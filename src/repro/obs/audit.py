"""Replayable decision audit log for the allocation control loops.

Every solver call an autoscaler makes — the initial provisioning solve,
drift-triggered rescales, and failure re-solves — is appended to an
:class:`AuditLog` as one JSON record carrying the *complete* solver
inputs (observed rates, caps, floor knobs, throughput corrections, time
budget, and a fingerprint of the previous allocation the incremental
re-solve chained from) and outputs (instance counts, $/h, a SHA-1 of the
slice assignment, and the alerts firing when the orchestrator annotated
the window).  Because the inputs are complete and the sim clock is
deterministic, :func:`replay_audit` can re-run the solver over the
logged chain and assert byte-identical allocations — turning every sim
run into a deterministic regression corpus for the solver stack.

The log is append-only: records are never mutated after the fact except
for :meth:`AuditLog.annotate`, which merges window-close context
(alerts firing) into the ``outputs`` of records appended earlier in the
*same* window, before the log is exported.

Validation is hand-rolled (:func:`validate_audit_record`), matching the
``SNAPSHOT_SCHEMA`` convention in :mod:`repro.obs.metrics` — no
jsonschema dependency.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "AUDIT_SCHEMA", "AuditLog", "allocation_fingerprint",
    "validate_audit_record", "replay_audit",
]

_KINDS = ("initial", "rescale", "failure")
_SCOPES = ("cluster", "fleet", "regional")

# Hand-rolled schema notation (documentation + validator contract), in
# the style of metrics.SNAPSHOT_SCHEMA.
AUDIT_SCHEMA: dict = {
    "type": "object",
    "required": ["seq", "t", "kind", "scope", "inputs", "outputs"],
    "properties": {
        "seq": {"type": "integer"},              # 0-based append order
        "t": {"type": "number"},                 # sim time of the solve
        "kind": {"enum": list(_KINDS)},
        "scope": {"enum": list(_SCOPES)},
        "inputs": {
            "type": "object",
            "required": ["rates", "over_provision", "caps", "chip_caps",
                         "min_ondemand_frac", "replacement_delay_s",
                         "time_budget_s", "tput_scale", "prev"],
            "properties": {
                # list (cluster) or {model|home: list} (fleet/regional)
                "rates": {"type": ["array", "object"]},
                "over_provision": {"type": "number"},
                "caps": {"type": "object"},
                "chip_caps": {"type": "object"},
                "min_ondemand_frac": {"type": "number"},
                "replacement_delay_s": {"type": "number"},
                "time_budget_s": {"type": "number"},
                "tput_scale": {"type": "object"},
                # fingerprint of the allocation the incremental re-solve
                # chained from; null for the initial solve
                "prev": {"type": ["object", "null"]},
                "models": {"type": "array"},     # fleet partial re-solves
            },
        },
        "outputs": {
            "type": "object",
            "required": ["counts", "cost_per_hour"],
            "properties": {
                "counts": {"type": "object"},
                "cost_per_hour": {"type": "number"},
                "assignment_sha": {"type": ["string", "null"]},
                "optimal": {"type": "boolean"},
                "solve_stats": {"type": ["object", "null"]},
                "per_model": {"type": "object"},
                "alerts_firing": {"type": "array"},
            },
        },
    },
}

_INPUT_NUMBERS = ("over_provision", "min_ondemand_frac",
                  "replacement_delay_s", "time_budget_s")
_INPUT_OBJECTS = ("caps", "chip_caps", "tput_scale")


def allocation_fingerprint(counts: Mapping[str, int],
                           assignment=None) -> dict:
    """Compact identity of one allocation: counts plus a SHA-1 over the
    slice assignment (byte-identity of the solver's actual decision, not
    just the aggregated instance counts)."""
    fp: dict = {"counts": {g: int(n) for g, n in sorted(counts.items())
                           if n}}
    fp["assignment_sha"] = (
        None if assignment is None else hashlib.sha1(
            np.asarray(assignment, dtype=np.int64).tobytes()).hexdigest())
    return fp


def validate_audit_record(rec: object) -> list[str]:
    """Validate one audit record against :data:`AUDIT_SCHEMA`.  Returns a
    list of problems (empty means valid)."""
    errs: list[str] = []
    if not isinstance(rec, dict):
        return [f"record must be an object, got {type(rec).__name__}"]
    if not isinstance(rec.get("seq"), int) or rec.get("seq", -1) < 0:
        errs.append(f"seq must be a non-negative int: {rec.get('seq')!r}")
    if not isinstance(rec.get("t"), (int, float)):
        errs.append(f"t must be a number: {rec.get('t')!r}")
    if rec.get("kind") not in _KINDS:
        errs.append(f"kind invalid: {rec.get('kind')!r}")
    if rec.get("scope") not in _SCOPES:
        errs.append(f"scope invalid: {rec.get('scope')!r}")
    ins = rec.get("inputs")
    if not isinstance(ins, dict):
        return errs + ["missing/invalid 'inputs' object"]
    if not isinstance(ins.get("rates"), (list, dict)):
        errs.append("inputs.rates must be an array or object")
    for k in _INPUT_NUMBERS:
        if not isinstance(ins.get(k), (int, float)):
            errs.append(f"inputs.{k} must be a number: {ins.get(k)!r}")
    for k in _INPUT_OBJECTS:
        if not isinstance(ins.get(k), dict):
            errs.append(f"inputs.{k} must be an object: {ins.get(k)!r}")
    if "prev" not in ins:
        errs.append("inputs.prev missing (null for the initial solve)")
    elif ins["prev"] is not None and not isinstance(ins["prev"], dict):
        errs.append("inputs.prev must be an object or null")
    if rec.get("kind") == "initial" and ins.get("prev") is not None:
        errs.append("initial solve must carry prev=null")
    outs = rec.get("outputs")
    if not isinstance(outs, dict):
        return errs + ["missing/invalid 'outputs' object"]
    if not isinstance(outs.get("counts"), dict):
        errs.append("outputs.counts must be an object")
    if not isinstance(outs.get("cost_per_hour"), (int, float)):
        errs.append("outputs.cost_per_hour must be a number")
    sha = outs.get("assignment_sha")
    if sha is not None and not isinstance(sha, str):
        errs.append("outputs.assignment_sha must be a string or null")
    alerts = outs.get("alerts_firing")
    if alerts is not None and (
            not isinstance(alerts, list)
            or any(not isinstance(a, str) for a in alerts)):
        errs.append("outputs.alerts_firing must be a string array")
    return errs


def _jsonable(v):
    """Numpy scalars/arrays -> plain JSON types (floats via repr, so the
    round-trip is exact)."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class AuditLog:
    """Append-only JSONL decision log (see module docstring).

    The autoscalers call :meth:`record_solve` after every successful
    solver call; the owning orchestrator keeps ``now`` pointed at the sim
    clock and attaches window context via :meth:`annotate`.
    """

    # exposed as a method so autoscalers reach the fingerprint through
    # the (duck-typed) log instance and repro.core never imports repro.obs
    fingerprint = staticmethod(allocation_fingerprint)

    def __init__(self, scope: str = "cluster"):
        if scope not in _SCOPES:
            raise ValueError(f"scope must be one of {_SCOPES}: {scope!r}")
        self.scope = scope
        self.records: list[dict] = []
        self.now: float = 0.0            # sim time, maintained by the owner

    def __len__(self) -> int:
        return len(self.records)

    def record_solve(self, *, kind: str, inputs: dict,
                     counts: Mapping, cost_per_hour: float,
                     assignment=None, optimal: Optional[bool] = None,
                     solve_stats=None, extra: Optional[dict] = None) -> dict:
        """Append one solve record.  ``inputs`` must carry the complete
        argument set the solver was called with (the schema's required
        input keys); ``assignment`` is hashed, never stored raw."""
        outputs = allocation_fingerprint(counts, assignment) \
            if assignment is not None or not isinstance(
                next(iter(counts.values()), 0), dict) \
            else {"counts": {m: {g: int(n) for g, n in sorted(c.items())
                                 if n}
                             for m, c in sorted(counts.items())},
                  "assignment_sha": None}
        outputs["cost_per_hour"] = float(cost_per_hour)
        if optimal is not None:
            outputs["optimal"] = bool(optimal)
        if solve_stats is not None:
            outputs["solve_stats"] = (
                solve_stats if isinstance(solve_stats, dict)
                else solve_stats.to_dict())
        if extra:
            outputs.update(_jsonable(extra))
        rec = {"seq": len(self.records), "t": float(self.now),
               "kind": kind, "scope": self.scope,
               "inputs": _jsonable(inputs), "outputs": outputs}
        errs = validate_audit_record(rec)
        if errs:
            raise ValueError("invalid audit record: " + "; ".join(errs))
        self.records.append(rec)
        return rec

    def annotate(self, start: int, **extra) -> None:
        """Merge window-close context (e.g. ``alerts_firing=[...]``) into
        the outputs of every record appended at index >= ``start``."""
        for rec in self.records[start:]:
            rec["outputs"].update(_jsonable(extra))

    def validate(self) -> list[str]:
        errs: list[str] = []
        for i, rec in enumerate(self.records):
            errs += [f"records[{i}]: {e}" for e in validate_audit_record(rec)]
            if rec["seq"] != i:
                errs.append(f"records[{i}]: seq {rec['seq']} out of order")
        return errs

    # -- serialization -------------------------------------------------------
    def to_jsonl(self) -> str:
        lines = [json.dumps({"scope": self.scope,
                             "n_records": len(self.records)})]
        lines.extend(json.dumps(r, sort_keys=True) for r in self.records)
        return "\n".join(lines) + "\n"

    def save(self, path) -> None:
        Path(path).write_text(self.to_jsonl())

    @classmethod
    def from_jsonl(cls, text: str) -> "AuditLog":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty audit log")
        head = json.loads(lines[0])
        log = cls(head.get("scope", "cluster"))
        for ln in lines[1:]:
            rec = json.loads(ln)
            errs = validate_audit_record(rec)
            if errs:
                raise ValueError(
                    f"invalid audit record (seq {rec.get('seq')}): "
                    + "; ".join(errs))
            log.records.append(rec)
        return log

    @classmethod
    def load(cls, path) -> "AuditLog":
        return cls.from_jsonl(Path(path).read_text())


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------
def _common_kwargs(ins: dict) -> dict:
    return {
        "over_provision": float(ins["over_provision"]),
        "caps": {g: int(v) for g, v in ins["caps"].items()} or None,
        "chip_caps": ({k: int(v) for k, v in ins["chip_caps"].items()}
                      or None),
        "min_ondemand_frac": float(ins["min_ondemand_frac"]),
        "replacement_delay_s": float(ins["replacement_delay_s"]),
        "time_budget_s": float(ins["time_budget_s"]),
        "tput_scale": ({g: (v if isinstance(v, (int, float))
                            else np.asarray(v, dtype=float))
                        for g, v in ins["tput_scale"].items()} or None),
    }


def _mismatches(seq: int, kind: str, want: dict, got: dict) -> list[dict]:
    out = []
    if want["counts"] != got["counts"]:
        out.append({"seq": seq, "kind": kind, "field": "counts",
                    "want": want["counts"], "got": got["counts"]})
    if (want.get("assignment_sha") is not None
            and want["assignment_sha"] != got["assignment_sha"]):
        out.append({"seq": seq, "kind": kind, "field": "assignment_sha",
                    "want": want["assignment_sha"],
                    "got": got["assignment_sha"]})
    return out


def replay_audit(solver, records: Sequence[dict]) -> list[dict]:
    """Re-run the logged solve chain and diff each allocation against the
    recorded outputs.  Returns a list of mismatch dicts — empty means
    every re-solve reproduced its logged allocation byte-identical.

    ``solver`` must be the same kind of allocator the log came from
    (``Melange`` for scope "cluster", ``MelangeFleet`` for "fleet",
    ``RegionalMelange`` for "regional"), constructed identically to the
    original run (profiling is deterministic, so rebuilding it from the
    same catalog/model/SLO suffices).  The chain starts at the logged
    ``initial`` record and threads each re-solve's ``prev`` exactly as
    the live autoscaler did.
    """
    from repro.core.workload import Workload
    if not records:
        return []
    scope = records[0]["scope"]
    mism: list[dict] = []
    if scope == "cluster":
        state = None
        for rec in records:
            ins = rec["inputs"]
            wl = Workload(solver.buckets,
                          np.asarray(ins["rates"], dtype=float),
                          name="replay")
            new = solver.allocate(
                wl, prev=None if rec["kind"] == "initial" else state,
                **_common_kwargs(ins))
            if new is None:
                mism.append({"seq": rec["seq"], "kind": rec["kind"],
                             "field": "feasible",
                             "want": rec["outputs"]["counts"], "got": None})
                return mism
            got = allocation_fingerprint(new.counts,
                                         new.solution.assignment)
            mism += _mismatches(rec["seq"], rec["kind"],
                                rec["outputs"], got)
            state = new
        return mism
    if scope == "regional":
        state = None
        for rec in records:
            ins = rec["inputs"]
            demand = {h: Workload(solver.profiles.buckets,
                                  np.asarray(r, dtype=float),
                                  name=f"replay:{h}")
                      for h, r in sorted(ins["rates"].items())}
            new = solver.allocate(
                demand, prev=None if rec["kind"] == "initial" else state,
                **_common_kwargs(ins))
            if new is None:
                mism.append({"seq": rec["seq"], "kind": rec["kind"],
                             "field": "feasible",
                             "want": rec["outputs"]["counts"], "got": None})
                return mism
            got = allocation_fingerprint(new.counts,
                                         new.solution.assignment)
            mism += _mismatches(rec["seq"], rec["kind"],
                                rec["outputs"], got)
            state = new
        return mism
    if scope == "fleet":
        per_model: dict = {}
        for rec in records:
            ins = rec["inputs"]
            models = list(ins.get("models") or sorted(ins["rates"]))
            wls = {m: Workload(solver.members[m].buckets,
                               np.asarray(ins["rates"][m], dtype=float),
                               name=f"replay:{m}") for m in models}
            prev = (None if rec["kind"] == "initial"
                    else {m: per_model[m] for m in models})
            new = solver.allocate(wls, models=models, prev=prev,
                                  **_common_kwargs(ins))
            if new is None:
                mism.append({"seq": rec["seq"], "kind": rec["kind"],
                             "field": "feasible",
                             "want": rec["outputs"]["counts"], "got": None})
                return mism
            want_pm = rec["outputs"].get("per_model") or {}
            for m in models:
                a = new.per_model[m]
                got = allocation_fingerprint(a.counts,
                                             a.solution.assignment)
                want = want_pm.get(m)
                if want is not None:
                    mism += _mismatches(rec["seq"], f"{rec['kind']}:{m}",
                                        want, got)
                per_model[m] = a
        return mism
    raise ValueError(f"unknown audit scope {scope!r}")
