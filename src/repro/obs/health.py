"""Fleet health engine: SLO burn-rate alerting + throughput-drift detection.

The PR 6 obs stack records what happened; this module *watches* it on
the sim clock:

* **Multi-window, multi-burn-rate SLO alerting** — the SRE recipe: an
  error-budget burn rate is ``(1 - attainment) / (1 - slo_target)``,
  and a rule fires only when BOTH a long and a short horizon exceed the
  rule's threshold (the long window keeps the alert significant, the
  short one makes it reset fast once the problem stops).  Horizons are
  counted in telemetry windows, so the engine is agnostic to the sim's
  window length.  Attainment is tracked fleet-wide and per
  (model | region) via ``WindowRecord.per_model`` drill-down, each key
  with its own alert lifecycle.
* **Cost-anomaly rule** — realized fleet ``$/h`` (``WindowRecord.
  cost_rate``) vs. the solver's predicted cost rate: a sustained gap
  beyond ``cost_tolerance`` in either direction means the fleet is
  billing meaningfully off-plan (orphaned instances, a reclaim storm
  re-billing launches, or a solver cost-model bug).
* **Alert lifecycle with hysteresis** — breach streaks move an alert
  ``pending -> firing`` after ``for_windows`` consecutive breaches, and
  ``firing -> resolved`` after ``clear_windows`` consecutive clears; a
  pending alert that clears is discarded silently.  Every transition is
  recorded with its sim time.
* **Throughput-drift detection** (:class:`ThroughputDriftDetector`) —
  per (gpu variant, bucket), observed serving behaviour is compared
  against the solver's ``MaxTput`` belief.  Under-performance is caught
  via sustained TPOT breach (the engine is slower than modeled, so the
  allocation sized on the model saturates); over-performance via a
  witness rate (an instance demonstrably served more than the corrected
  prediction while meeting the SLO).  Corrections are EWMA-smoothed,
  clamped, and *sticky*: with no fresh evidence a correction holds —
  decay-to-one would re-create the bad allocation and oscillate.  The
  published corrections feed the autoscalers' ``tput_scale``, where a
  changed column's load row re-opens exactly its slices in
  ``solve_incremental``.

The engine is orchestrator-agnostic: it consumes ``WindowRecord``-shaped
objects plus optional pre-aggregated drift evidence, and is fully
testable standalone on synthetic windows.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "BurnRateRule", "DEFAULT_BURN_RULES", "Alert", "HealthUpdate",
    "ThroughputDriftDetector", "FleetHealthEngine",
]

PENDING = "pending"
FIRING = "firing"
RESOLVED = "resolved"


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """One fast/slow window pair: fire when the burn rate over the last
    ``long_windows`` AND the last ``short_windows`` telemetry windows
    both exceed ``burn_threshold``."""

    name: str
    long_windows: int
    short_windows: int
    burn_threshold: float

    def __post_init__(self):
        if self.short_windows < 1 or self.long_windows < self.short_windows:
            raise ValueError(
                f"rule {self.name}: need 1 <= short <= long windows, got "
                f"{self.short_windows}/{self.long_windows}")
        if self.burn_threshold <= 0:
            raise ValueError(
                f"rule {self.name}: burn_threshold must be positive")


# The classic page/ticket split, scaled to sim telemetry windows: the
# fast pair catches budget burning ~8x over a short horizon, the slow
# pair catches a steady 4x leak over a day-scale horizon.
DEFAULT_BURN_RULES: tuple[BurnRateRule, ...] = (
    BurnRateRule("slo-fast-burn", long_windows=6, short_windows=1,
                 burn_threshold=8.0),
    BurnRateRule("slo-slow-burn", long_windows=24, short_windows=4,
                 burn_threshold=4.0),
)

COST_RULE = "cost-anomaly"
DRIFT_RULE = "tput-drift"


@dataclasses.dataclass
class Alert:
    """One (rule, key) alert instance walking the lifecycle."""

    rule: str
    key: str                  # "" fleet-wide, else "model=x" / "gpu=y" ...
    state: str                # pending | firing | resolved
    since_t: float            # sim time the current state was entered
    breaches: int = 0         # consecutive breached windows
    clears: int = 0           # consecutive clear windows
    value: float = 0.0        # magnitude at last breach (burn rate, ...)

    @property
    def label(self) -> str:
        return f"{self.rule}[{self.key}]" if self.key else self.rule

    def to_dict(self) -> dict:
        return {"rule": self.rule, "key": self.key, "state": self.state,
                "since_t": self.since_t, "value": round(self.value, 4)}


@dataclasses.dataclass
class HealthUpdate:
    """What one window's observation changed."""

    t: float
    transitions: list[dict]            # {t, rule, key, state, value}
    firing: list[str]                  # labels of alerts firing now

    @property
    def any_firing(self) -> bool:
        return bool(self.firing)


class ThroughputDriftDetector:
    """Per-(gpu variant, bucket) correction factors for the solver's
    MaxTput belief (see module docstring for the signal design).

    ``observe`` consumes one window's served-request evidence:
    ``served`` is an iterable of ``(gpu_name, bucket_index, tpot_s)``
    tuples for completed requests, ``n_instances`` the live instance
    count per variant, ``window_s`` the window length.  Returns True
    when the *published* corrections moved (re-solve worthy).
    """

    def __init__(self, max_tput: Mapping[str, Sequence[float]],
                 slo_tpot_s: float, *,
                 rel_tolerance: float = 0.25,
                 ewma: float = 0.5,
                 min_requests: int = 8,
                 sustain_windows: int = 2,
                 publish_tolerance: float = 0.10,
                 clamp: tuple[float, float] = (0.25, 4.0)):
        if not 0 < ewma <= 1:
            raise ValueError(f"ewma must be in (0, 1]: {ewma}")
        if slo_tpot_s <= 0:
            raise ValueError(f"slo_tpot_s must be positive: {slo_tpot_s}")
        self.max_tput = {g: np.asarray(v, dtype=float)
                         for g, v in max_tput.items()}
        self.slo = float(slo_tpot_s)
        self.rel_tolerance = rel_tolerance
        self.ewma = ewma
        self.min_requests = min_requests
        self.sustain_windows = sustain_windows
        self.publish_tolerance = publish_tolerance
        self.clamp = clamp
        self.correction = {g: np.ones(len(v))
                           for g, v in self.max_tput.items()}
        self._published = {g: np.ones(len(v))
                           for g, v in self.max_tput.items()}
        self._streak = {g: np.zeros(len(v), dtype=int)
                        for g, v in self.max_tput.items()}

    def observe(self, served, n_instances: Mapping[str, int],
                window_s: float) -> bool:
        stats: dict[tuple[str, int], list] = {}
        for gpu, b, tpot in served:
            if gpu not in self.max_tput:
                continue
            stats.setdefault((gpu, int(b)), []).append(float(tpot))
        dt = max(float(window_s), 1e-9)
        tol = self.rel_tolerance
        seen: set[tuple[str, int]] = set()
        for (gpu, b), tpots in stats.items():
            corr = self.correction[gpu]
            if b >= len(corr):
                continue
            n = len(tpots)
            if n < self.min_requests:
                continue
            seen.add((gpu, b))
            mean_tpot = float(np.mean(tpots))
            inst = max(1, int(n_instances.get(gpu, 1)))
            per_inst_rate = n / dt / inst
            eff = self.max_tput[gpu][b] * corr[b]
            target = None
            if mean_tpot > self.slo * (1 + tol):
                # under-performance: the engine takes mean_tpot per token
                # where the SLO budgeted slo — the believed throughput is
                # off by about that ratio
                target = self.slo / mean_tpot
            elif (eff > 0 and per_inst_rate > eff * (1 + tol)
                  and mean_tpot <= self.slo * (1 + 1e-9)):
                # over-performance witness: an instance sustained more
                # than the *corrected* prediction while in SLO — raises
                # the correction back up, which is also the recovery path
                # after a transient under-performance episode
                target = per_inst_rate / self.max_tput[gpu][b]
            if target is None:
                # no fresh evidence: hold the correction (sticky — see
                # module docstring)
                self._streak[gpu][b] = (
                    self._streak[gpu][b] + 1
                    if abs(corr[b] - 1.0) > tol else 0)
                continue
            new = (1 - self.ewma) * corr[b] + self.ewma * target
            corr[b] = float(np.clip(new, *self.clamp))
            self._streak[gpu][b] = (self._streak[gpu][b] + 1
                                    if abs(corr[b] - 1.0) > tol else 0)
        # cells with no fresh evidence this window decay their drift
        # streak: a GPU the corrected re-solve stopped routing to stops
        # *alerting* after a few quiet windows, while its published
        # correction stays in force (sticky — see module docstring)
        for g, st in self._streak.items():
            for b in range(len(st)):
                if st[b] > 0 and (g, b) not in seen:
                    st[b] -= 1
        return self._publish()

    def _publish(self) -> bool:
        changed = False
        for g, corr in self.correction.items():
            pub = self._published[g]
            sustained = self._streak[g] >= self.sustain_windows
            active = np.abs(pub - 1.0) > 1e-12
            candidate = np.where(sustained | active, corr, pub)
            moved = np.abs(candidate - pub) / np.maximum(pub, 1e-9)
            if np.any(moved > self.publish_tolerance):
                self._published[g] = candidate.copy()
                changed = True
        return changed

    def corrections(self) -> dict[str, np.ndarray]:
        """Published per-bucket corrections, only for variants that carry
        a non-unit correction (absent variants mean "trust the model")."""
        return {g: pub.copy() for g, pub in self._published.items()
                if np.any(np.abs(pub - 1.0) > 1e-12)}

    def drifted(self) -> dict[str, float]:
        """Variants currently drifted (sustained): worst correction per
        variant — the drift-alert evidence."""
        out: dict[str, float] = {}
        for g, corr in self.correction.items():
            mask = self._streak[g] >= self.sustain_windows
            if np.any(mask):
                worst = corr[mask][np.argmax(np.abs(corr[mask] - 1.0))]
                out[g] = float(worst)
        return out


class FleetHealthEngine:
    """Watches a stream of ``WindowRecord``s (see module docstring)."""

    def __init__(self, *, slo_target: float = 0.995,
                 burn_rules: Sequence[BurnRateRule] = DEFAULT_BURN_RULES,
                 for_windows: int = 2, clear_windows: int = 2,
                 cost_tolerance: float = 0.5,
                 att_dim: str = "model"):
        if not 0 < slo_target < 1:
            raise ValueError(f"slo_target must be in (0, 1): {slo_target}")
        if for_windows < 1 or clear_windows < 1:
            raise ValueError("for_windows/clear_windows must be >= 1")
        self.slo_target = slo_target
        self.error_budget = 1.0 - slo_target
        self.burn_rules = tuple(burn_rules)
        self.for_windows = for_windows
        self.clear_windows = clear_windows
        self.cost_tolerance = cost_tolerance
        self.att_dim = att_dim
        horizon = max((r.long_windows for r in self.burn_rules), default=1)
        # per-window {key: (slo_ok, completed + dropped)}; key "" is the
        # fleet-wide series, others are per-(model|region) drill-downs
        self._hist: deque[dict[str, tuple[int, int]]] = deque(maxlen=horizon)
        self.alerts: dict[tuple[str, str], Alert] = {}   # active
        self.resolved: list[Alert] = []
        self.transitions: list[dict] = []

    # -- burn-rate math ------------------------------------------------------
    def _burn(self, key: str, n_windows: int) -> Optional[float]:
        """Burn rate over the trailing ``n_windows`` for ``key`` (None
        when the horizon holds no traffic for that key)."""
        ok = denom = 0
        hist = list(self._hist)[-n_windows:]
        for w in hist:
            s, d = w.get(key, (0, 0))
            ok += s
            denom += d
        if denom == 0:
            return None
        return (1.0 - ok / denom) / self.error_budget

    # -- lifecycle -----------------------------------------------------------
    def _transition(self, t: float, a: Alert) -> dict:
        tr = {"t": t, "rule": a.rule, "key": a.key, "state": a.state,
              "value": round(a.value, 4)}
        self.transitions.append(tr)
        return tr

    def _update_state(self, t: float, rule: str, key: str,
                      breach: bool, value: float,
                      new_tr: list[dict]) -> None:
        k = (rule, key)
        a = self.alerts.get(k)
        if breach:
            if a is None:
                a = Alert(rule, key, PENDING, t, breaches=1, value=value)
                self.alerts[k] = a
                new_tr.append(self._transition(t, a))
                if a.breaches >= self.for_windows:   # for_windows == 1
                    a.state = FIRING
                    a.since_t = t
                    new_tr.append(self._transition(t, a))
                return
            a.breaches += 1
            a.clears = 0
            a.value = value
            if a.state == PENDING and a.breaches >= self.for_windows:
                a.state = FIRING
                a.since_t = t
                new_tr.append(self._transition(t, a))
            return
        if a is None:
            return
        a.clears += 1
        a.breaches = 0
        if a.state == PENDING:
            del self.alerts[k]          # never fired: discard silently
            return
        if a.clears >= self.clear_windows:
            a.state = RESOLVED
            a.since_t = t
            new_tr.append(self._transition(t, a))
            self.resolved.append(a)
            del self.alerts[k]

    # -- main entry ----------------------------------------------------------
    def observe_window(self, rec, *,
                       predicted_cost_rate: Optional[float] = None,
                       drift: Sequence[tuple[str, bool, float]] = ()
                       ) -> HealthUpdate:
        """Consume one closed telemetry window.

        ``rec`` is ``WindowRecord``-shaped (``t1``, ``slo_ok``,
        ``completed``, ``dropped``, ``cost_rate``, ``per_model``).
        ``predicted_cost_rate`` is the solver's current planned $/h.
        ``drift`` carries pre-computed drift evidence per gpu variant:
        ``(gpu_name, breached, worst_correction)``.
        """
        t = float(rec.t1)
        window: dict[str, tuple[int, int]] = {
            "": (rec.slo_ok, rec.completed + rec.dropped)}
        for m, d in (rec.per_model or {}).items():
            window[f"{self.att_dim}={m}"] = (
                d.get("slo_ok", 0),
                d.get("completed", 0) + d.get("dropped", 0))
        self._hist.append(window)
        new_tr: list[dict] = []
        keys = {k for w in self._hist for k in w}
        for rule in self.burn_rules:
            for key in sorted(keys):
                long_burn = self._burn(key, rule.long_windows)
                short_burn = self._burn(key, rule.short_windows)
                if long_burn is None:
                    continue
                breach = (long_burn > rule.burn_threshold
                          and short_burn is not None
                          and short_burn > rule.burn_threshold)
                self._update_state(t, rule.name, key, breach,
                                   long_burn, new_tr)
        if predicted_cost_rate is not None and predicted_cost_rate > 0:
            ratio = float(rec.cost_rate) / float(predicted_cost_rate)
            breach = abs(ratio - 1.0) > self.cost_tolerance
            self._update_state(t, COST_RULE, "", breach, ratio, new_tr)
        for gpu, breach, worst in drift:
            self._update_state(t, DRIFT_RULE, f"gpu={gpu}", breach,
                               worst, new_tr)
        return HealthUpdate(t, new_tr, self.firing())

    # -- views ---------------------------------------------------------------
    def firing(self) -> list[str]:
        return sorted(a.label for a in self.alerts.values()
                      if a.state == FIRING)

    def firing_by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for a in self.alerts.values():
            if a.state == FIRING:
                out[a.rule] = out.get(a.rule, 0) + 1
        return out

    def summary(self) -> dict:
        """Alert roll-up for reports and benchmark artifacts."""
        return {
            "slo_target": self.slo_target,
            "firing": self.firing(),
            "active": [a.to_dict() for a in self.alerts.values()],
            "resolved": [a.to_dict() for a in self.resolved],
            "transitions": list(self.transitions),
        }
