"""Architecture config registry."""
from .archs import ARCHS
from .base import LayerSpec, ModelConfig


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]()


def list_archs():
    return sorted(ARCHS)
