"""Model configuration schema.

A model is a stack of *groups*; each group is a repeating *period* of layer
specs (e.g. gemma2 = [(local, global)] × 23, jamba = one 8-layer period × 9).
Period-grouping is what lets the stack lower as `lax.scan` over stacked
parameters — essential to keep HLO size and compile time sane for the 512-chip
dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # "attn" | "mamba" | "rwkv"
    attn_type: str = "global"   # "global" | "local" | "cross"
    mlp: str = "dense"          # "dense" | "moe" | "none"


Group = Tuple[Tuple[LayerSpec, ...], int]   # (period, repeat)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    groups: Tuple[Group, ...]

    # attention options
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    use_post_norms: bool = False          # gemma2-style post-block norms

    # mlp
    mlp_act: str = "swiglu"               # swiglu | gelu | relu2

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "capacity"            # capacity | dense (oracle)
    # >0: GShard group-capacity dispatch — index math + gathers batched over
    # this many token blocks so SPMD partitions them locally (§Perf lever)
    moe_block_dispatch: int = 0

    # Mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    mamba_dt_rank: int = 0                # 0 -> ceil(d_model / 16)

    # RWKV
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32

    # modality frontends (stubs)
    n_codebooks: int = 0                  # musicgen EnCodec streams
    n_vision_tokens: int = 0              # llama-vision patch embeddings

    tie_embeddings: bool = False
    # pad the vocab so it divides the model-parallel axis (perf lever:
    # un-shardable vocabs replicate the logits compute; see §Perf)
    vocab_pad_to: int = 0

    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    optimizer: str = "adamw"              # adamw | adafactor
    remat: bool = True

    # --- derived -----------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(len(period) * rep for period, rep in self.groups)

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_attention_free(self) -> bool:
        return all(spec.kind != "attn"
                   for period, _ in self.groups for spec in period)

    @property
    def has_subquadratic_context(self) -> bool:
        """True if long-context decode (500K) is feasible: any non-attn layer
        or sliding-window keeps the dominant state sub-linear in context."""
        kinds = [spec for period, _ in self.groups for spec in period]
        if any(s.kind in ("mamba", "rwkv") for s in kinds):
            return True
        if self.sliding_window is not None:
            return True
        return False

    def layer_specs(self):
        for period, rep in self.groups:
            for _ in range(rep):
                yield from period

    def param_count(self) -> int:
        """Exact parameter count (matches init_params)."""
        from repro.models import transformer
        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import transformer
        return transformer.count_params(self, active_only=True)

    def reduced(self, *, repeat_cap: int = 2, d_model: int = 64,
                vocab: int = 128) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = 4
        kv = max(1, min(self.n_kv_heads, 2))
        rwkv_hd = 16
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=d_model * 2,
            vocab_size=vocab,
            groups=tuple((period, min(rep, repeat_cap))
                         for period, rep in self.groups),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=d_model if self.n_experts else 0,
            mamba_dt_rank=8,
            mamba_d_state=8,
            rwkv_head_dim=rwkv_hd,
            rwkv_lora_decay=8,
            rwkv_lora_mix=8,
            sliding_window=(32 if self.sliding_window is not None else None),
            n_vision_tokens=16 if self.n_vision_tokens else 0,
            dtype="float32",
            param_dtype="float32",
        )


def uniform_groups(spec: LayerSpec, n_layers: int) -> Tuple[Group, ...]:
    return (((spec,), n_layers),)
