"""The 10 assigned architectures, exact configs from the assignment table.

Sources noted per entry; every dimension below matches the assignment block.
"""
from __future__ import annotations

from .base import LayerSpec, ModelConfig, uniform_groups

ATTN = LayerSpec(kind="attn", attn_type="global", mlp="dense")


def musicgen_large() -> ModelConfig:
    # [audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
    # decoder-only over EnCodec tokens [arXiv:2306.05284]. 4 codebook streams;
    # the EnCodec frontend is a stub (token ids in, summed embeddings).
    return ModelConfig(
        name="musicgen-large", family="audio",
        d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=2048,
        groups=uniform_groups(ATTN, 48),
        mlp_act="gelu", n_codebooks=4,
    )


def granite_moe_1b() -> ModelConfig:
    # [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
    # [hf:ibm-granite/granite-3.0-1b-a400m-base]
    moe_layer = LayerSpec(kind="attn", attn_type="global", mlp="moe")
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155,
        groups=uniform_groups(moe_layer, 24),
        n_experts=32, moe_top_k=8, moe_d_ff=512,
        tie_embeddings=True,
    )


def kimi_k2_1t() -> ModelConfig:
    # [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
    # MoE 384e top-8 [arXiv:2501.kimi2] — trillion-param MoE (paper-table).
    moe_layer = LayerSpec(kind="attn", attn_type="global", mlp="moe")
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
        d_ff=2048, vocab_size=163840,
        groups=(((ATTN,), 1), ((moe_layer,), 60)),   # first layer dense
        n_experts=384, moe_top_k=8, moe_d_ff=2048,
        optimizer="adafactor",
    )


def minitron_4b() -> ModelConfig:
    # [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
    # pruned nemotron [arXiv:2407.14679]; squared-ReLU non-gated MLP.
    return ModelConfig(
        name="minitron-4b", family="dense",
        d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=9216, vocab_size=256000,
        groups=uniform_groups(ATTN, 32),
        mlp_act="relu2",
    )


def qwen2_1_5b() -> ModelConfig:
    # [dense] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
    # GQA, QKV bias [arXiv:2407.10671]; tied embeddings.
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936,
        groups=uniform_groups(ATTN, 28),
        qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    )


def internlm2_1_8b() -> ModelConfig:
    # [dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544
    return ModelConfig(
        name="internlm2-1.8b", family="dense",
        d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=92544,
        groups=uniform_groups(ATTN, 24),
        rope_theta=1_000_000.0,
    )


def gemma2_27b() -> ModelConfig:
    # [dense] 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
    # local+global alternating, logit softcap [arXiv:2408.00118].
    local = LayerSpec(kind="attn", attn_type="local", mlp="dense")
    return ModelConfig(
        name="gemma2-27b", family="dense",
        d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=36864, vocab_size=256000,
        groups=(((local, ATTN), 23),),
        mlp_act="geglu",
        attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
        use_post_norms=True,
    )


def llama32_vision_11b() -> ModelConfig:
    # [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
    # cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].
    # Pattern: every 5th layer cross-attends to precomputed patch embeddings
    # (vision tower is a stub per the assignment).
    cross = LayerSpec(kind="attn", attn_type="cross", mlp="dense")
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=128256,
        groups=(((cross, ATTN, ATTN, ATTN, ATTN), 8),),
        rope_theta=500_000.0, n_vision_tokens=1600,
    )


def jamba_1_5_large() -> ModelConfig:
    # [hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
    # MoE 16e top-2 — Mamba+attn 1:7 interleave [arXiv:2403.19887].
    # Period of 8: attn at index 3, mamba elsewhere; MoE on odd layers.
    m_d = LayerSpec(kind="mamba", mlp="dense")
    m_e = LayerSpec(kind="mamba", mlp="moe")
    a_e = LayerSpec(kind="attn", attn_type="global", mlp="moe")
    period = (m_d, m_e, m_d, a_e, m_d, m_e, m_d, m_e)
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=65536,
        groups=((period, 9),),
        n_experts=16, moe_top_k=2, moe_d_ff=24576,
        mamba_d_state=16, mamba_expand=2, mamba_conv=4,
        optimizer="adafactor",
    )


def rwkv6_1_6b() -> ModelConfig:
    # [ssm] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
    # RWKV-6 "Finch" — data-dependent decay [arXiv:2404.05892].
    rwkv = LayerSpec(kind="rwkv", mlp="none")
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=7168, vocab_size=65536,
        groups=uniform_groups(rwkv, 24),
        rwkv_head_dim=64,
    )


ARCHS = {
    "musicgen-large": musicgen_large,
    "granite-moe-1b-a400m": granite_moe_1b,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "minitron-4b": minitron_4b,
    "qwen2-1.5b": qwen2_1_5b,
    "internlm2-1.8b": internlm2_1_8b,
    "gemma2-27b": gemma2_27b,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "rwkv6-1.6b": rwkv6_1_6b,
}
