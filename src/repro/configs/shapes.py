"""Assigned input-shape cases (per-arch applicability included).

LM transformer shapes are seq_len × global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache); ``train_4k``
lowers ``train_step``; ``prefill_32k`` lowers the prefill step.

``long_500k`` requires sub-quadratic context handling — it is skipped for the
pure full-attention architectures (recorded in DESIGN.md §Arch-applicability)
and runs for the SSM / hybrid / sliding-window ones.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524_288, 1),
}


def list_shapes():
    return list(SHAPES)


def get_shape(name: str) -> ShapeCase:
    return SHAPES[name]


def applicable(cfg: ModelConfig, case: ShapeCase) -> tuple[bool, str]:
    """(runnable?, reason-if-not)."""
    if case.name == "long_500k" and not cfg.has_subquadratic_context:
        return False, (
            "pure full-attention arch: 500K-token decode requires "
            "sub-quadratic context (see DESIGN.md §Arch-applicability)")
    return True, ""


def cells(configs: dict[str, ModelConfig]):
    """All (arch, shape) cells incl. skipped ones, for the roofline table."""
    out = []
    for arch, cfg in configs.items():
        for case in SHAPES.values():
            ok, reason = applicable(cfg, case)
            out.append((arch, case.name, ok, reason))
    return out
