"""Synthetic trace generators: diurnal curves, bursts, mix drift, spot
preemption storms.  All seeded and reproducible; every generator returns a
``WorkloadTrace`` built from piecewise-constant segments, so generated and
JSON-loaded traces are interchangeable everywhere downstream.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import numpy as np

from .trace import FleetEvent, TraceSegment, WorkloadTrace


def synth_trace(duration_s: float, segment_s: float,
                rate_fn: Callable[[float], float],
                mix_fn: Callable[[float], dict[str, float]],
                *, name: str = "synth", seed: int = 0) -> WorkloadTrace:
    """Sample ``rate_fn``/``mix_fn`` at segment midpoints into a trace."""
    segs = []
    t = 0.0
    while t < duration_s - 1e-9:
        d = min(segment_s, duration_s - t)
        mid = t + d / 2
        segs.append(TraceSegment(t, d, max(0.0, float(rate_fn(mid))),
                                 dict(mix_fn(mid))))
        t += d
    return WorkloadTrace(name, segs, seed=seed)


def diurnal_trace(base_rate: float, peak_rate: float, *,
                  duration_s: float = 24 * 3600.0,
                  segment_s: float = 3600.0,
                  peak_frac: float = 14 / 24,
                  dataset: str = "mixed",
                  mix: Optional[dict[str, float]] = None,
                  name: str = "diurnal", seed: int = 0) -> WorkloadTrace:
    """Sinusoidal day curve: trough ``base_rate``, crest ``peak_rate`` at
    ``peak_frac`` of the trace (default 2pm of a 24h day).  ``segment_s``
    sets the piecewise resolution; pass a compressed ``duration_s`` to run
    a "24h" shape in minutes of simulated time."""
    m = mix or {dataset: 1.0}

    def rate(t: float) -> float:
        phase = 2 * math.pi * (t / duration_s - peak_frac)
        return base_rate + (peak_rate - base_rate) * 0.5 * (1 + math.cos(phase))

    return synth_trace(duration_s, segment_s, rate, lambda _t: m,
                       name=name, seed=seed)


def regional_diurnal_traces(
        rates: "dict[str, tuple[float, float]]", *,
        duration_s: float = 24 * 3600.0,
        segment_s: float = 3600.0,
        peak_fracs: Optional[dict[str, float]] = None,
        dataset: str = "mixed",
        mix: Optional[dict[str, float]] = None,
        name: str = "regional", seed: int = 0
) -> "dict[str, WorkloadTrace]":
    """Per-region diurnal rate curves: ``rates`` maps home region ->
    (trough, crest) req/s, and each region's day peaks at its own local
    time — by default the peaks are spread evenly across the trace
    (timezone offsets), which is exactly the follow-the-sun shape that
    makes geo-distributed pooling pay: one region's crest lands in
    another's trough.  ``peak_fracs`` overrides the per-region peak
    position (fraction of the trace).  Seeds are decorrelated per region
    in sorted-name order, so realizations stay reproducible."""
    homes = sorted(rates)
    if peak_fracs is None:
        peak_fracs = {h: (14 / 24 + k / len(homes)) % 1.0
                      for k, h in enumerate(homes)}
    out: dict[str, WorkloadTrace] = {}
    for k, h in enumerate(homes):
        base, peak = rates[h]
        out[h] = diurnal_trace(
            base, peak, duration_s=duration_s, segment_s=segment_s,
            peak_frac=peak_fracs[h], dataset=dataset, mix=mix,
            name=f"{name}:{h}", seed=seed + k)
    return out


def mix_drift_trace(rate: float, start_mix: dict[str, float],
                    end_mix: dict[str, float], *,
                    duration_s: float, segment_s: float,
                    name: str = "mix-drift", seed: int = 0) -> WorkloadTrace:
    """Constant rate, dataset mix interpolating linearly start -> end
    (e.g. arena -> mixed as long-document traffic ramps up)."""
    keys = sorted(set(start_mix) | set(end_mix))

    def mix(t: float) -> dict[str, float]:
        a = min(1.0, max(0.0, t / duration_s))
        m = {k: (1 - a) * start_mix.get(k, 0.0) + a * end_mix.get(k, 0.0)
             for k in keys}
        return {k: v for k, v in m.items() if v > 0}

    return synth_trace(duration_s, segment_s, lambda _t: rate, mix,
                       name=name, seed=seed)


def inject_bursts(trace: WorkloadTrace, *, n_bursts: int,
                  magnitude: float = 3.0, burst_s: float = 120.0,
                  seed: int = 0) -> WorkloadTrace:
    """Multiply the rate by ``magnitude`` inside ``n_bursts`` randomly-placed
    windows.  Segments overlapping a burst are split at the burst edges, so
    the rest of the schedule is untouched."""
    rng = np.random.default_rng(seed)
    starts = np.sort(rng.uniform(0, max(1e-9, trace.duration - burst_s),
                                 size=n_bursts))
    windows = [(float(s), float(s + burst_s)) for s in starts]

    def burst_factor(a: float, b: float) -> float:
        mid = (a + b) / 2
        return magnitude if any(w0 <= mid < w1 for w0, w1 in windows) else 1.0

    cuts: list[float] = []
    for w0, w1 in windows:
        cuts += [w0, w1]
    segs = []
    for s in trace.segments:
        edges = sorted({s.t_start, s.t_end,
                        *[c for c in cuts if s.t_start < c < s.t_end]})
        for a, b in zip(edges[:-1], edges[1:]):
            segs.append(TraceSegment(a, b - a, s.rate * burst_factor(a, b),
                                     dict(s.mix)))
    return WorkloadTrace(f"{trace.name}+bursts", segs, list(trace.events),
                         trace.seed)


def preemption_events(gpus: Sequence[str], *, duration_s: float,
                      events_per_hour: float = 0.5,
                      stockout_prob: float = 0.3,
                      restock_after_s: Optional[float] = None,
                      seed: int = 0) -> list[FleetEvent]:
    """Spot-market stand-in: Poisson preemption arrivals over the trace,
    each killing one instance of a uniformly-chosen type; with probability
    ``stockout_prob`` the type also stocks out (optionally restocking after
    ``restock_after_s``)."""
    rng = np.random.default_rng(seed)
    out: list[FleetEvent] = []
    n = int(rng.poisson(events_per_hour * duration_s / 3600.0))
    times = np.sort(rng.uniform(0, duration_s, size=n))
    for t in times:
        gpu = str(rng.choice(list(gpus)))
        stock = bool(rng.random() < stockout_prob)
        out.append(FleetEvent(float(t), "preemption", gpu, 1, stockout=stock))
        if stock and restock_after_s is not None:
            t_r = float(t + restock_after_s)
            if t_r < duration_s:
                out.append(FleetEvent(t_r, "restock", gpu))
    # restocks are appended next to their stockout, i.e. *after* later
    # preemptions — sort so the stream is a valid (time-monotone) event
    # schedule before it ever reaches a WorkloadTrace or an orchestrator
    out.sort(key=lambda e: e.t)
    return out
