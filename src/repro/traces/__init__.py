"""Time-varying workload traces (rate curves, mix drift, fleet events)."""
from .trace import (FleetEvent, RealizedTrace, TraceSegment, WorkloadTrace)
from .generators import (diurnal_trace, inject_bursts, mix_drift_trace,
                         preemption_events, regional_diurnal_traces,
                         synth_trace)
