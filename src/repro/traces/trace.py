"""Time-varying workload traces as first-class objects.

A ``WorkloadTrace`` is a piecewise-constant schedule of (request rate,
dataset mix) over simulated time, plus a stream of fleet events (spot
preemptions, stockouts, restocks).  Traces are seeded and fully
reproducible: ``realize()`` turns the schedule into concrete request
arrivals and sizes, deterministically per seed.  Traces round-trip through
JSON so recorded scenarios can be replayed and shared.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

from repro.core.workload import (DATASETS, INPUT_EDGES, OUTPUT_EDGES,
                                 Workload, workload_from_samples)


@dataclasses.dataclass(frozen=True)
class TraceSegment:
    """Constant-rate interval: ``rate`` req/s with a dataset mix."""

    t_start: float
    duration: float
    rate: float
    mix: dict[str, float]              # dataset name -> weight (sums to 1)

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """Capacity event injected into the orchestrator at time ``t``.

    kind: "preemption" (instances killed; with ``stockout`` the type also
    becomes unavailable for replacement), "stockout" (cap the type at its
    current count without killing anything), "restock" (lift the cap).
    """

    t: float
    kind: str
    gpu: str
    n: int = 1
    stockout: bool = False


@dataclasses.dataclass
class RealizedTrace:
    """Concrete draw from a trace: per-request arrivals and sizes."""

    arrivals: np.ndarray               # (n,) seconds, sorted
    input_lens: np.ndarray             # (n,) int
    output_lens: np.ndarray            # (n,) int

    @property
    def n(self) -> int:
        return len(self.arrivals)


def _validate_mix(mix: dict[str, float]) -> dict[str, float]:
    unknown = set(mix) - set(DATASETS)
    if unknown:
        raise ValueError(f"unknown datasets in mix: {sorted(unknown)}")
    tot = sum(mix.values())
    if tot <= 0:
        raise ValueError("mix weights must sum to a positive value")
    return {k: v / tot for k, v in mix.items() if v > 0}


@dataclasses.dataclass
class WorkloadTrace:
    name: str
    segments: list[TraceSegment]
    events: list[FleetEvent] = dataclasses.field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.segments = sorted(self.segments, key=lambda s: s.t_start)
        self.events = list(self.events)     # no aliasing of caller lists
        # events are an execution schedule: require the caller to hand
        # them over time-sorted instead of silently reordering (a
        # generator emitting an unsorted stream is a bug worth surfacing
        # — see preemption_events' restock interleaving)
        for e in self.events:
            if not np.isfinite(e.t) or e.t < 0:
                raise ValueError(
                    f"trace '{self.name}': event at t={e.t!r} is not a "
                    "finite non-negative time")
        for a, b in zip(self.events[:-1], self.events[1:]):
            if b.t < a.t:
                raise ValueError(
                    f"trace '{self.name}': events not time-sorted "
                    f"({a.kind}@{a.t} precedes {b.kind}@{b.t}); sort the "
                    "stream before constructing the trace")

    # -- schedule queries ----------------------------------------------------
    @property
    def duration(self) -> float:
        return self.segments[-1].t_end if self.segments else 0.0

    def segment_at(self, t: float) -> Optional[TraceSegment]:
        for s in self.segments:
            if s.t_start <= t < s.t_end:
                return s
        return self.segments[-1] if self.segments and t >= self.duration \
            else None

    def rate_at(self, t: float) -> float:
        s = self.segment_at(t)
        return s.rate if s else 0.0

    def mix_at(self, t: float) -> dict[str, float]:
        s = self.segment_at(t)
        return dict(s.mix) if s else {}

    @property
    def peak_rate(self) -> float:
        return max((s.rate for s in self.segments), default=0.0)

    @property
    def mean_rate(self) -> float:
        d = self.duration
        if d <= 0:
            return 0.0
        return sum(s.rate * s.duration for s in self.segments) / d

    def windows(self, window_s: float) -> Iterator[tuple[float, float]]:
        t = 0.0
        while t < self.duration - 1e-9:
            yield t, min(t + window_s, self.duration)
            t += window_s

    @property
    def peak_time(self) -> float:
        return max(self.segments, key=lambda s: s.rate).t_start \
            if self.segments else 0.0

    def workload_at(self, t: float, *, n_samples: int = 20_000,
                    seed: Optional[int] = None,
                    input_edges=INPUT_EDGES,
                    output_edges=OUTPUT_EDGES) -> Workload:
        """Histogram ``Workload`` for the schedule at time ``t`` (rate +
        mix), for provisioning: the ILP consumes this directly.  Pass the
        profile's own edges (``grid_edges``) when provisioning against a
        non-default bucket grid."""
        rng = np.random.default_rng(self.seed if seed is None else seed)
        mix = _validate_mix(self.mix_at(t) or {"mixed": 1.0})
        ins, outs = [], []
        for ds, w in sorted(mix.items()):
            k = max(1, int(round(w * n_samples)))
            i, o = DATASETS[ds](rng, k)
            ins.append(i)
            outs.append(o)
        return workload_from_samples(np.concatenate(ins),
                                     np.concatenate(outs),
                                     self.rate_at(t),
                                     name=f"{self.name}@t={t:g}",
                                     input_edges=input_edges,
                                     output_edges=output_edges)

    # -- transforms ----------------------------------------------------------
    def scaled(self, factor: float) -> "WorkloadTrace":
        """Scale all rates by ``factor`` (events and timing unchanged)."""
        segs = [dataclasses.replace(s, rate=s.rate * factor)
                for s in self.segments]
        return WorkloadTrace(f"{self.name}x{factor:g}", segs,
                             list(self.events), self.seed)

    def with_events(self, events: list[FleetEvent]) -> "WorkloadTrace":
        merged = sorted(list(self.events) + list(events), key=lambda e: e.t)
        return WorkloadTrace(self.name, list(self.segments), merged,
                             self.seed)

    # -- realization ---------------------------------------------------------
    def realize(self, seed: Optional[int] = None) -> RealizedTrace:
        """Draw concrete requests: Poisson arrivals within each segment at
        the segment's rate; sizes sampled from the segment's dataset mix.
        Deterministic given the seed."""
        rng = np.random.default_rng(self.seed if seed is None else seed)
        arr_parts: list[np.ndarray] = []
        in_parts: list[np.ndarray] = []
        out_parts: list[np.ndarray] = []
        for s in self.segments:
            if s.rate <= 0 or s.duration <= 0:
                continue
            mix = _validate_mix(s.mix)
            # Poisson process restricted to the segment
            n_exp = s.rate * s.duration
            n = int(rng.poisson(n_exp))
            if n == 0:
                continue
            at = np.sort(rng.uniform(s.t_start, s.t_end, size=n))
            names = list(mix)
            pick = rng.choice(len(names), size=n, p=[mix[k] for k in names])
            ins = np.zeros(n, dtype=int)
            outs = np.zeros(n, dtype=int)
            for di, ds in enumerate(names):
                m = pick == di
                k = int(m.sum())
                if k == 0:
                    continue
                i, o = DATASETS[ds](rng, k)
                ins[m] = i
                outs[m] = o
            arr_parts.append(at)
            in_parts.append(ins)
            out_parts.append(outs)
        if not arr_parts:
            z = np.zeros(0)
            return RealizedTrace(z, z.astype(int), z.astype(int))
        arrivals = np.concatenate(arr_parts)
        order = np.argsort(arrivals, kind="stable")
        return RealizedTrace(arrivals[order],
                             np.concatenate(in_parts)[order],
                             np.concatenate(out_parts)[order])

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "seed": self.seed,
            "segments": [{
                "t_start": s.t_start, "duration": s.duration,
                "rate": s.rate, "mix": s.mix} for s in self.segments],
            "events": [{
                "t": e.t, "kind": e.kind, "gpu": e.gpu, "n": e.n,
                "stockout": e.stockout} for e in self.events],
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "WorkloadTrace":
        d = json.loads(text)
        return cls(
            name=d["name"],
            segments=[TraceSegment(s["t_start"], s["duration"], s["rate"],
                                   dict(s["mix"])) for s in d["segments"]],
            events=[FleetEvent(e["t"], e["kind"], e["gpu"], e.get("n", 1),
                               e.get("stockout", False))
                    for e in d.get("events", [])],
            seed=d.get("seed", 0),
        )

    def save(self, path) -> None:
        Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "WorkloadTrace":
        return cls.from_json(Path(path).read_text())
