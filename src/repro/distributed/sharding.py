"""Logical-axis sharding rules with divisibility-aware mapping.

The production meshes are ("data", "model") single-pod and
("pod", "data", "model") multi-pod.  Parameters and activations are annotated
with *logical* axis names; :func:`logical_to_spec` maps them to mesh axes,
replicating any tensor dimension whose size does not divide the mesh axis size
(e.g. qwen2's 12 query heads on a 16-way model axis, granite's 49155 vocab).

Model code calls :func:`constrain` with logical axis names; the launcher
installs a :class:`ShardingContext` (mesh + rules) before tracing. Outside a
context (unit tests, single-device smoke runs) ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis name -> mesh axes (in order of preference / outer-to-inner).
# "batch" spans the data-parallel axes (pod+data when multi-pod).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),              # unsharded by default; perf flag remaps -> ("model",)
    "kv_seq": (),           # KV-cache sequence dim; perf flag remaps -> ("data",)
    "model_d": (),          # residual/embedding feature dim: replicated
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "experts": ("model",),  # expert parallelism
    "expert_cap": ("pod", "data"),
    "expert_ff": ("pod", "data"),  # expert weight d_ff: FSDP-style over data
    "flat_tokens": ("pod", "data"),  # flattened (B*S)±topk token dims in MoE
    "d_inner": ("model",),  # mamba inner dim
    "rwkv_heads": ("model",),
    "conv": (),
    "state": (),
    "layers": (),           # stacked-layer leading axis
    "unsharded": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Sharding rule table; override entries for perf experiments."""

    rules: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def with_overrides(self, **overrides: tuple[str, ...]) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return ShardingRules(rules=merged)


def mesh_axes_size(sizes: Mapping[str, int], axes: Sequence[str]) -> int:
    total = 1
    for ax in axes:
        total *= sizes[ax]
    return total


def _resolve(
    axis_sizes: Mapping[str, int],
    logical_axes: Sequence[str | None],
    shape: Sequence[int] | None,
    rules: ShardingRules,
) -> P:
    spec: list[Any] = []
    used: set[str] = set()
    for i, name in enumerate(logical_axes):
        if name is None:
            spec.append(None)
            continue
        axes = tuple(
            a for a in rules.rules.get(name, ()) if a in axis_sizes and a not in used
        )
        if axes and shape is not None:
            # drop leading axes until the dim divides evenly (replicate if never)
            while axes and (shape[i] == 0 or shape[i] % mesh_axes_size(axis_sizes, axes) != 0):
                axes = axes[1:]
        if not axes:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else axes)
    return P(*spec)


def logical_to_spec(
    mesh: Mesh,
    logical_axes: Sequence[str | None],
    shape: Sequence[int] | None = None,
    rules: ShardingRules | None = None,
) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return _resolve(sizes, logical_axes, shape, rules or ShardingRules())


def named_sharding(
    mesh: Mesh,
    logical_axes: Sequence[str | None],
    shape: Sequence[int] | None = None,
    rules: ShardingRules | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(mesh, logical_axes, shape, rules))


# ---------------------------------------------------------------------------
# Trace-time sharding context (installed by the launcher around tracing).
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: ShardingRules | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Mesh | None, rules: ShardingRules | None = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules or ShardingRules()
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> ShardingRules:
    return _CTX.rules or ShardingRules()


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint using logical axes; no-op outside a context."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(mesh, logical_axes, x.shape, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(mesh: Mesh, tree_axes: Any, tree_shapes: Any,
                   rules: ShardingRules | None = None) -> Any:
    """Map a pytree of logical-axis tuples + matching shapes -> NamedShardings."""
    return jax.tree.map(
        lambda axes, shape: named_sharding(mesh, axes, shape, rules),
        tree_axes,
        tree_shapes,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v
        ),
    )
