"""CLI: ``python -m repro.analysis [paths] [--strict] [--json] ...``.

Exit status: 0 when clean (or when violations exist but ``--strict`` was
not given — advisory mode), 1 under ``--strict`` with unfiltered
violations or any parse error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import (RULES, lint_paths, load_baseline_entries,
                   write_baseline)

_PKG_ROOT = Path(__file__).resolve().parents[1]          # src/repro
_REPO_ROOT = Path(__file__).resolve().parents[3]         # repo checkout
_DEFAULT_BASELINE = _REPO_ROOT / ".lint-baseline.json"


def _default_paths() -> list[Path]:
    """src/repro plus the tests/ and benchmarks/ trees when present."""
    out = [_PKG_ROOT]
    for extra in ("benchmarks", "tests"):
        p = _REPO_ROOT / extra
        if p.is_dir():
            out.append(p)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the solver/simulator "
                    "contracts (stdlib-only). Default target: src/repro.")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories to lint (default: the repro "
                         "package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unfiltered violation")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to run")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="grandfathering baseline file (default: "
                         ".lint-baseline.json at the repo root, if present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current violations to the baseline file and "
                         "exit")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="rewrite the baseline file keeping only entries "
                         "that still match a violation, and exit")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--explain", metavar="RULE", default=None,
                    help="print a rule's full documentation and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(n) for n in RULES)
        for name in sorted(RULES):
            print(f"{name:<{width}}  {RULES[name].summary}")
        return 0

    if args.explain:
        cls = RULES.get(args.explain)
        if cls is None:
            print(f"unknown rule {args.explain!r}; known rules:",
                  ", ".join(sorted(RULES)), file=sys.stderr)
            return 2
        print(f"{cls.name} — {cls.summary}\n")
        print(cls.explain)
        return 0

    rule_names = args.rules.split(",") if args.rules else None
    paths = args.paths or _default_paths()

    baseline_path = args.baseline or (
        _DEFAULT_BASELINE if _DEFAULT_BASELINE.exists() else None)
    entries = None
    if baseline_path is not None and not args.no_baseline \
            and not args.write_baseline and Path(baseline_path).exists():
        entries = load_baseline_entries(baseline_path)

    if args.prune_baseline:
        if not entries:
            print("no baseline entries to prune")
            return 0
        # re-lint WITHOUT filtering, keep entries that still match
        raw = lint_paths(paths, rule_names)
        live = {v.fingerprint() for v in raw.violations}
        kept = [e for e in entries if e.get("fingerprint") in live]
        out = baseline_path
        Path(out).write_text(json.dumps(
            {"version": 1, "entries": kept}, indent=1) + "\n")
        print(f"pruned {len(entries) - len(kept)} stale entr"
              f"{'y' if len(entries) - len(kept) == 1 else 'ies'}; "
              f"{len(kept)} kept in {out}")
        return 0

    result = lint_paths(paths, rule_names, baseline_entries=entries)

    if args.write_baseline:
        out = args.baseline or _DEFAULT_BASELINE
        write_baseline(result.violations, out)
        print(f"wrote {len(result.violations)} entr"
              f"{'y' if len(result.violations) == 1 else 'ies'} to {out}")
        return 0

    if args.as_json:
        print(json.dumps({
            "violations": [v.to_dict() for v in result.violations],
            "files": result.n_files,
            "parse_errors": result.n_parse_errors,
            "baseline_filtered": result.baseline_filtered,
            "stale_baseline": len(result.stale_baseline),
            "rules": sorted(RULES) if rule_names is None else rule_names,
        }, indent=1))
    else:
        for v in result.violations:
            print(v.format())
            text = v.line_text.strip()
            if text:
                print(f"    {text}")
        tail = (f" ({result.baseline_filtered} grandfathered by baseline)"
                if result.baseline_filtered else "")
        if result.violations:
            print(f"{len(result.violations)} violation"
                  f"{'' if len(result.violations) == 1 else 's'} in "
                  f"{result.n_files} files{tail}")
        else:
            print(f"clean: {result.n_files} files, "
                  f"{len(rule_names or RULES)} rules{tail}")

    failed = result.violations or result.n_parse_errors
    return 1 if (args.strict and failed) else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:           # e.g. `... | head` closed the pipe
        sys.exit(0)
