"""Units-of-measure & aliasing dataflow analysis (stdlib-only).

Every headline number this reproduction produces — $/h savings, T/$
tables, SLO attainment — is the output of hand-written unit arithmetic
($/hr x h, tokens/s / req/s, GB/s x 1e9, RTT seconds subtracted from
TPOT budgets).  A silent unit mix-up corrupts the result without
failing a test.  This module gives the lint engine (PR 7's ``core``)
a genuine intraprocedural-dataflow + call-graph analysis:

* **Unit lattice.**  A :class:`Unit` is TOP (unknown), ANY (a bare
  numeric literal — polymorphic, adopts the other operand's unit), or a
  dimension-exponent product over the base dimensions ``s h tok B GB $
  flop Tflop`` (``tok/$`` is ``{tok: 1, $: -1}``).  Count-like
  pseudo-units (``req``, ``step``, ``seq``, ``chip``, ``instance``)
  normalize to dimensionless: the repo freely mixes per-request and
  absolute quantities, so ``req/s`` is tracked as ``1/s`` — which keeps
  ``r [req/s] * (i + o) [tok/req]`` equal to ``tok/s`` without a
  per-request schism, while still distinguishing $/h from $/s and tok
  from $.

* **Seeding.**  Units come from the repo's naming conventions
  (``*_s`` -> s, ``*_hr`` -> h, ``price_hr`` -> $/h, ``*_gbs`` -> GB/s,
  ``*_bytes`` -> B, ``tput``/``rate`` -> req/s, ``cost`` -> $,
  ``X_per_Y`` -> unit(X)/unit(Y), ...), from the explicit
  :data:`ANNOTATIONS` registry for names that defy their suffix
  (``preemption_rate`` is 1/h, not req/s), and from ``# unit: <expr>``
  comments on assignments, dataclass fields, function parameters
  (continuation lines of a ``def``) and returns (the ``def`` line).

* **Abstract interpretation.**  Assignments propagate units through
  function bodies; ``+``/``-``/comparisons/min/max of incompatible
  concrete units are violations; ``*``/``/`` compose units
  algebraically.  Recognized conversion literals (3600 = s/h, 1e9 =
  B/GB, 1e12 = flop/Tflop) apply their unit only when it cancels
  against the other operand, so ``r * (i + o) * 3600.0 / acc.price_hr``
  checks out as tok/$ while ``n * 3600`` stays a plain count.

* **Interprocedural flow.**  Function summaries (parameter units,
  declared + inferred return unit) resolve calls within a module and —
  via :func:`project_summaries` — across the solver/serving modules, so
  a function returning seconds cannot be added to hours at a call site
  three files away.

* **Aliasing / param-mutation.**  :func:`param_mutations` runs a
  root-alias analysis over a function body and flags in-place mutation
  (``x[...] = ``, augmented assigns, ``.sort()``/``.fill()``, ``out=``
  kwargs) of ndarrays reachable from parameters — the caller-owned
  in-place rebind bug class PR 8 shipped and had to hot-fix — unless
  the function is on :data:`SANCTIONED_MUTATORS` or the line is
  pragma'd.

Violation *reporting* stays in ``rules.py``; this module only computes.
Everything here is stdlib ``ast``/``re`` — the analysis must not change
the environment it guards.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Optional, Sequence, Union

# ---------------------------------------------------------------------------
# the unit lattice
# ---------------------------------------------------------------------------

#: canonical spellings for unit atoms in ``# unit:`` expressions and the
#: conventions table.  Mapping to "" means dimensionless (count-like).
_ALIASES = {
    "s": "s", "sec": "s", "secs": "s", "second": "s", "seconds": "s",
    "h": "h", "hr": "h", "hrs": "h", "hour": "h", "hours": "h",
    "tok": "tok", "toks": "tok", "token": "tok", "tokens": "tok",
    "b": "B", "byte": "B", "bytes": "B",
    "gb": "GB", "gib": "GB",
    "$": "$", "usd": "$", "dollar": "$", "dollars": "$",
    "flop": "flop", "flops": "flop",
    "tflop": "Tflop", "tflops": "Tflop",
    # count-like pseudo-units: normalized to dimensionless (see module doc)
    "req": "", "reqs": "", "request": "", "requests": "",
    "step": "", "steps": "", "seq": "", "seqs": "",
    "chip": "", "chips": "", "inst": "", "instance": "", "instances": "",
    "slice": "", "slices": "", "block": "", "blocks": "",
    "1": "", "one": "",
}


class Unit:
    """TOP (unknown), ANY (polymorphic literal), or a dims product."""

    __slots__ = ("kind", "dims")

    def __init__(self, kind: str, dims: dict | None = None):
        self.kind = kind                       # "top" | "any" | "dim"
        self.dims = tuple(sorted((dims or {}).items()))

    # -- constructors ------------------------------------------------------
    @staticmethod
    def of(dims: dict) -> "Unit":
        return Unit("dim", {k: v for k, v in dims.items() if v})

    # -- predicates --------------------------------------------------------
    @property
    def is_top(self) -> bool:
        return self.kind == "top"

    @property
    def is_any(self) -> bool:
        return self.kind == "any"

    @property
    def concrete(self) -> bool:
        return self.kind == "dim"

    @property
    def dimensionless(self) -> bool:
        return self.kind == "dim" and not self.dims

    def __eq__(self, other) -> bool:
        return (isinstance(other, Unit) and self.kind == other.kind
                and self.dims == other.dims)

    def __hash__(self) -> int:
        return hash((self.kind, self.dims))

    # -- algebra -----------------------------------------------------------
    def _combine(self, other: "Unit", sign: int) -> "Unit":
        if self.is_top or other.is_top:
            return TOP
        if self.is_any:
            return other if sign > 0 else other.inv()
        if other.is_any:
            return self
        d = dict(self.dims)
        for k, v in other.dims:
            d[k] = d.get(k, 0) + sign * v
        return Unit.of(d)

    def mul(self, other: "Unit") -> "Unit":
        return self._combine(other, +1)

    def div(self, other: "Unit") -> "Unit":
        return self._combine(other, -1)

    def inv(self) -> "Unit":
        if not self.concrete:
            return self
        return Unit.of({k: -v for k, v in self.dims})

    def pow(self, n: int) -> "Unit":
        if not self.concrete:
            return self
        return Unit.of({k: v * n for k, v in self.dims})

    def __str__(self) -> str:
        if self.is_top:
            return "?"
        if self.is_any:
            return "<literal>"
        num = [f"{k}^{v}" if v > 1 else k for k, v in self.dims if v > 0]
        den = [f"{k}^{-v}" if v < -1 else k for k, v in self.dims if v < 0]
        if not num and not den:
            return "1"
        head = "*".join(num) if num else "1"
        return head + ("/" + "*".join(den) if den else "")

    __repr__ = __str__


TOP = Unit("top")
ANY = Unit("any")
DIMLESS = Unit.of({})


class TupleUnit:
    """Units of a fixed-arity tuple value (e.g. ``(req/s, s)`` returns)."""

    __slots__ = ("elts",)

    def __init__(self, elts: Sequence[Unit]):
        self.elts = tuple(elts)

    def __eq__(self, other) -> bool:
        return isinstance(other, TupleUnit) and self.elts == other.elts

    def __hash__(self) -> int:
        return hash(self.elts)

    def __str__(self) -> str:
        return "(" + ", ".join(str(e) for e in self.elts) + ")"

    __repr__ = __str__


AbstractUnit = Union[Unit, TupleUnit]


def compatible(a: AbstractUnit, b: AbstractUnit) -> bool:
    """Whether ``a`` and ``b`` may legally meet in +/-/comparison."""
    if isinstance(a, TupleUnit) or isinstance(b, TupleUnit):
        if isinstance(a, TupleUnit) and isinstance(b, TupleUnit):
            return (len(a.elts) == len(b.elts)
                    and all(compatible(x, y)
                            for x, y in zip(a.elts, b.elts)))
        return True          # tuple vs scalar: don't judge
    if not a.concrete or not b.concrete:
        return True
    return a.dims == b.dims


def join(a: AbstractUnit, b: AbstractUnit) -> AbstractUnit:
    """Most informative unit consistent with both (for env merges)."""
    if isinstance(a, TupleUnit) or isinstance(b, TupleUnit):
        if (isinstance(a, TupleUnit) and isinstance(b, TupleUnit)
                and len(a.elts) == len(b.elts)):
            return TupleUnit([join(x, y) for x, y in zip(a.elts, b.elts)])
        return TOP
    if a.concrete and b.concrete:
        return a if a.dims == b.dims else TOP
    if a.concrete:
        return a
    if b.concrete:
        return b
    return ANY if (a.is_any and b.is_any) else TOP


# ---------------------------------------------------------------------------
# parsing ``# unit: <expr>``
# ---------------------------------------------------------------------------

UNIT_COMMENT_RE = re.compile(r"#\s*unit:\s*([^#]+?)\s*$")

_TOKEN_RE = re.compile(r"\s*([A-Za-z$][\w$]*|-?\d+(?:\.\d+)?|\*\*|[*/()^,])")


def parse_unit(text: str) -> AbstractUnit:
    """Parse a unit expression: ``$ / h``, ``tok/$``, ``GB/s``, ``B/tok``,
    ``1/h``, ``s^2``, or a tuple ``(req/s, s)``.  Raises ValueError."""
    text = text.strip()
    if text.startswith("(") and text.endswith(")"):
        inner = text[1:-1]
        if "," in inner:
            return TupleUnit([parse_unit(p) for p in inner.split(",")])
        text = inner
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise ValueError(f"bad unit expression {text!r}")
        toks.append(m.group(1))
        pos = m.end()
    if not toks:
        raise ValueError("empty unit expression")
    unit, op, i = DIMLESS, "*", 0
    while i < len(toks):
        t = toks[i]
        if t in ("*", "/"):
            op, i = t, i + 1
            continue
        atom = _atom_unit(t)
        if atom is None:
            raise ValueError(f"unknown unit atom {t!r} in {text!r}")
        i += 1
        if i + 1 < len(toks) and toks[i] in ("^", "**"):
            atom = atom.pow(int(toks[i + 1]))
            i += 2
        unit = unit.mul(atom) if op == "*" else unit.div(atom)
        op = "*"
    return unit


def _atom_unit(tok: str) -> Optional[Unit]:
    canon = _ALIASES.get(tok, _ALIASES.get(tok.lower()))
    if canon is None:
        return None
    return DIMLESS if canon == "" else Unit.of({canon: 1})


def _u(text: str) -> Unit:
    out = parse_unit(text)
    assert isinstance(out, Unit)
    return out


# ---------------------------------------------------------------------------
# seeding: registry + naming conventions
# ---------------------------------------------------------------------------

#: Explicit annotation registry: bare names whose unit defies their
#: suffix (or that have no suffix).  Matched on variable names, attribute
#: names, function names (return units), and parameter names — after
#: stripping leading underscores.  Extend here rather than sprinkling
#: ``# unit:`` comments when a name recurs across modules.
ANNOTATIONS: dict[str, str] = {
    # accelerators / catalog
    "preemption_rate": "1/h",       # reclaims per instance-hour, not req/s
    "eff_flops": "flop/s",
    "eff_bw": "B/s",
    "flops_tf": "Tflop/s",          # peak TFLOP/s, not "tera-floating-ops"
    "price_mult": "1",
    "spot_mult": "1",
    "preemption_mult": "1",
    # profiles / load matrix
    "max_tput": "req/s",
    "tputs": "req/s",
    "costs": "$/h",                 # the ILP cost vector is $/h per column
    "availability": "1",
    # engine model
    "prefill_rate": "tok/s",        # tokens/s, not requests/s
    "tokens_per_dollar": "tok/$",
    "decode_step_time": "s",
    "rate_and_tpot": "(req/s, s)",
    "kv_avg_occupancy": "1",
    "mfu": "1",
    "bw_util": "1",
    # simulator / orchestrator
    "rate_fn": "req/s",
    "ewma": "req/s",
    "drift": "1",
    "attainment": "1",
    "cost_rate": "$/h",             # fleet burn rate, not a req/s rate
}

#: Suffix/naming conventions, first match wins (compounds before plain
#: suffixes).  Applied after registry lookup and ``X_per_Y`` splitting.
CONVENTIONS: list[tuple[str, str]] = [
    (r"(^|_)price_hr$", "$/h"),
    (r"(^|_)cost_hr$", "$/h"),
    (r"(^|_)price_s$", "$/s"),
    (r"(^|_)gbs$", "GB/s"),
    (r"(^|_)gb$", "GB"),
    (r"(^|_)bytes?$", "B"),
    (r"(^|_)tokens?$|(^|_)toks$", "tok"),
    (r"(^|_)(s|secs?|seconds?)$", "s"),
    (r"(^|_)(hrs?|hours?)$", "h"),
    (r"(^|_)tf$", "Tflop/s"),
    (r"(^|_)tputs?$|throughput", "req/s"),
    (r"(^|_)rates?$", "req/s"),
    (r"^n_|^num_|(^|_)counts?$", "1"),
    (r"(^|_)(frac|fraction|pct|share|util|efficiency|occupancy|reserve)$",
     "1"),
    (r"(^|_)cost$", "$"),
    (r"^slo_|(^|_)slo$", "s"),
    (r"^tpot|(^|_)tpot$", "s"),
    (r"^ttft|(^|_)ttft$", "s"),
    (r"^rtt$|^rtt_|(^|_)rtt$", "s"),
    (r"(^|_)(time|latency|delay|duration|deadline)$", "s"),
]

_COMPILED_CONVENTIONS = [(re.compile(p), u) for p, u in CONVENTIONS]

#: Conversion-factor literals.  Their unit is applied in * and / ONLY
#: when it cancels against the other operand (which must carry one of
#: the factor's base dimensions); otherwise the literal stays
#: polymorphic.  ``x_hr * 3600`` -> s; ``x_s / 3600`` -> h;
#: ``count * 3600`` -> count.
CONVERSIONS: dict[float, str] = {
    3600.0: "s/h",
    1e9: "B/GB",
    1e-9: "GB/B",
    1e12: "flop/Tflop",
}


def seed_unit(name: str) -> Optional[AbstractUnit]:
    """Unit a bare name suggests (registry, X_per_Y, suffix conventions);
    None when the name carries no convention."""
    name = name.lstrip("_")
    if not name:
        return None
    ann = ANNOTATIONS.get(name)
    if ann is not None:
        return parse_unit(ann)
    if "_per_" in name:
        left, _, right = name.partition("_per_")
        lu = seed_unit(left) if left else None
        if lu is None and left:
            lu = _atom_unit(left.rsplit("_", 1)[-1])
        ru = _atom_unit(right)
        if isinstance(lu, Unit) and ru is not None:
            return lu.div(ru)
    for rx, unit in _COMPILED_CONVENTIONS:
        if rx.search(name):
            return _u(unit)
    return None


# ---------------------------------------------------------------------------
# function summaries
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FuncSummary:
    name: str                      # bare name
    qualname: str                  # "Class.method" or bare name
    params: dict[str, AbstractUnit] = dataclasses.field(default_factory=dict)
    param_order: list[str] = dataclasses.field(default_factory=list)
    ret: AbstractUnit = TOP        # declared if present, else inferred
    ret_declared: Optional[AbstractUnit] = None
    ret_inferred: AbstractUnit = TOP
    is_property: bool = False


class _Imports:
    """Minimal import-alias resolution (mirrors FileLint.qualname)."""

    def __init__(self, tree: ast.AST):
        self.aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and not node.level:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def qualname(self, node: ast.AST) -> Optional[str]:
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.aliases.get(node.id, node.id))
        return ".".join(reversed(parts))


# calls whose result carries the first argument's (or receiver's) unit
_PASSTHROUGH_FNS = {
    "abs", "float", "int", "round", "sorted", "reversed", "sum",
    "math.floor", "math.ceil", "math.fabs", "math.fsum",
    "numpy.abs", "numpy.sum", "numpy.mean", "numpy.median", "numpy.sort",
    "numpy.min", "numpy.max", "numpy.cumsum", "numpy.diff", "numpy.ravel",
    "numpy.asarray", "numpy.array", "numpy.copy", "numpy.ascontiguousarray",
    "numpy.full_like", "numpy.percentile", "numpy.quantile", "numpy.where",
}
_PASSTHROUGH_METHODS = {
    "copy", "astype", "reshape", "ravel", "tolist", "sum", "mean", "min",
    "max", "cumsum", "clip", "item", "squeeze", "flatten", "get",
}
# math fns returning dimensionless regardless of (dimensionless-ish) input
_DIMLESS_FNS = {
    "len", "math.log", "math.log2", "math.log10", "math.exp", "math.isnan",
    "math.isinf", "math.isfinite", "numpy.isfinite", "numpy.isnan",
    "numpy.isinf", "numpy.argmin", "numpy.argmax", "numpy.argsort",
    "numpy.count_nonzero", "numpy.sign", "bool", "numpy.log", "numpy.log2",
    "numpy.exp",
}
_MINMAX_FNS = {"min", "max", "numpy.minimum", "numpy.maximum"}
_ISCLOSE_FNS = {"math.isclose", "numpy.isclose", "numpy.allclose"}


class ModuleUnits:
    """Unit analysis of one module: summaries + violations.

    ``external`` maps bare/qualified callee names to FuncSummary from
    other modules (see :func:`project_summaries`).
    """

    def __init__(self, source: str, rel: str,
                 external: Optional[dict[str, FuncSummary]] = None,
                 tree: Optional[ast.AST] = None):
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree if tree is not None else ast.parse(source,
                                                            filename=rel)
        self.imports = _Imports(self.tree)
        self.external = external or {}
        self.violations: list[tuple[ast.AST, str]] = []
        #: per-line ``# unit:`` annotations (1-based), parse errors noted
        self.line_units: dict[int, AbstractUnit] = {}
        #: named form ``# unit: i: tok, o: tok, return: req/s`` — used on
        #: one-line ``def`` signatures to type params + return at once
        self.line_named: dict[int, dict[str, AbstractUnit]] = {}
        self._scan_unit_comments()
        #: attribute/field name -> unit, from annotated class fields here
        self.field_units: dict[str, Unit] = {}
        #: function summaries, keyed by bare name AND qualname
        self.summaries: dict[str, FuncSummary] = {}
        self._functions: list[tuple[ast.AST, str, dict]] = []
        self.module_env: dict[str, AbstractUnit] = {}
        self._collect()
        self._fixed_point()

    # -- setup -------------------------------------------------------------
    @staticmethod
    def _split_commas(text: str) -> list[str]:
        """Split on top-level commas (commas inside parens don't count)."""
        parts, depth, cur = [], 0, []
        for ch in text:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        parts.append("".join(cur))
        return parts

    def _scan_unit_comments(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            m = UNIT_COMMENT_RE.search(line)
            if not m:
                continue
            text = m.group(1).strip()
            try:
                if ":" in text and not text.startswith("("):
                    named = {}
                    for part in self._split_commas(text):
                        name, _, expr = part.partition(":")
                        if not name.strip() or not expr.strip():
                            raise ValueError(
                                f"bad named unit entry {part!r}")
                        named[name.strip()] = parse_unit(expr.strip())
                    self.line_named[i] = named
                else:
                    self.line_units[i] = parse_unit(text)
            except ValueError as e:
                self.violations.append((_FakeNode(i), f"bad # unit: {e}"))

    def _collect(self) -> None:
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._functions.append((node, node.name, {}))
            elif isinstance(node, ast.ClassDef):
                for stmt in node.body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self._functions.append(
                            (stmt, f"{node.name}.{stmt.name}", {}))
                    elif isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name):
                        u = self.line_units.get(stmt.lineno)
                        if isinstance(u, Unit):
                            self.field_units[stmt.target.id] = u
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                self._module_assign(node)
        for fn, qual, _ in self._functions:
            self.summaries[qual] = self._initial_summary(fn, qual)
        # bare-name access: last definition wins unless ambiguous
        for fn, qual, _ in self._functions:
            bare = qual.rsplit(".", 1)[-1]
            if bare != qual:
                prev = self.summaries.get(bare)
                cur = self.summaries[qual]
                if prev is not None and prev.ret != cur.ret:
                    continue                       # ambiguous: keep first
                self.summaries.setdefault(bare, cur)

    def _module_assign(self, node: ast.AST) -> None:
        u = self.line_units.get(node.lineno)
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, ast.Name):
                if u is not None:
                    self.module_env[t.id] = u

    def _initial_summary(self, fn: ast.AST, qual: str) -> FuncSummary:
        args = list(getattr(fn.args, "posonlyargs", [])) + fn.args.args \
            + fn.args.kwonlyargs
        params: dict[str, AbstractUnit] = {}
        order: list[str] = []
        by_line: dict[int, list[ast.arg]] = {}
        for a in args:
            by_line.setdefault(a.lineno, []).append(a)
        named = self.line_named.get(fn.lineno, {})
        for a in args:
            if a.arg in ("self", "cls"):
                continue
            order.append(a.arg)
            u: Optional[AbstractUnit] = named.get(a.arg)
            if u is None and a.lineno > fn.lineno \
                    and a.lineno in self.line_units:
                u = self.line_units[a.lineno]
            if u is None:
                u = seed_unit(a.arg)
            params[a.arg] = u if u is not None else TOP
        declared = named.get("return", named.get("ret"))
        if declared is None:
            declared = self.line_units.get(fn.lineno)
        if declared is None:
            declared = seed_unit(fn.name)
        is_prop = any(
            isinstance(d, ast.Name) and d.id == "property"
            or isinstance(d, ast.Attribute) and d.attr in ("property",
                                                           "cached_property")
            for d in fn.decorator_list)
        return FuncSummary(fn.name, qual, params, order,
                           ret=declared if declared is not None else TOP,
                           ret_declared=declared, is_property=is_prop)

    # -- the fixed point ---------------------------------------------------
    def _fixed_point(self) -> None:
        for final in (False, True):
            for fn, qual, _ in self._functions:
                s = self.summaries[qual]
                interp = _FnInterp(self, fn, s, report=final)
                ret = interp.run()
                s.ret_inferred = ret
                if s.ret_declared is None:
                    s.ret = ret
                elif final:
                    self._check_declared_ret(fn, s)

    def _check_declared_ret(self, fn: ast.AST, s: FuncSummary) -> None:
        dec, inf = s.ret_declared, s.ret_inferred
        if isinstance(dec, Unit) and isinstance(inf, Unit) \
                and dec.concrete and inf.concrete and not inf.dimensionless \
                and dec.dims != inf.dims:
            self.violations.append((
                fn, f"return of {s.qualname}() is declared "
                    f"'{dec}' but body infers '{inf}'"))

    # -- lookup surface used by the interpreter ----------------------------
    def lookup_callee(self, name: str) -> Optional[FuncSummary]:
        return self.summaries.get(name) or self.external.get(name)

    def attr_unit(self, attr: str) -> Optional[AbstractUnit]:
        """Unit of an attribute access by bare attribute name."""
        if attr in self.field_units:
            return self.field_units[attr]
        s = self.lookup_callee(attr)
        if s is not None and s.is_property:
            return s.ret
        return seed_unit(attr)


class _FakeNode:
    """Line anchor for violations with no AST node (comment parses)."""

    def __init__(self, lineno: int):
        self.lineno = lineno
        self.col_offset = 0


class _FnInterp:
    """Forward abstract interpreter over one function body."""

    def __init__(self, mod: ModuleUnits, fn: ast.AST, summary: FuncSummary,
                 report: bool, outer_env: Optional[dict] = None):
        self.mod = mod
        self.fn = fn
        self.summary = summary
        self.report = report
        self.env: dict[str, AbstractUnit] = dict(outer_env or {})
        self.env.update(summary.params)
        self.returns: list[AbstractUnit] = []

    # -- plumbing ----------------------------------------------------------
    def _flag(self, node: ast.AST, msg: str) -> None:
        if self.report:
            self.mod.violations.append((node, msg))

    def _name_unit(self, name: str) -> AbstractUnit:
        if name in self.env:
            return self.env[name]
        if name in self.mod.module_env:
            return self.mod.module_env[name]
        u = seed_unit(name)
        return u if u is not None else TOP

    def run(self) -> AbstractUnit:
        for stmt in self.fn.body:
            self._stmt(stmt, self.env)
        concrete = [r for r in self.returns
                    if isinstance(r, TupleUnit)
                    or (isinstance(r, Unit) and r.concrete)]
        if not concrete:
            return TOP
        out = concrete[0]
        for r in concrete[1:]:
            out = join(out, r)
        return out

    # -- statements --------------------------------------------------------
    def _stmt(self, s: ast.stmt, env: dict) -> None:
        if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._assign(s, env)
        elif isinstance(s, ast.Return):
            u = self._infer(s.value, env) if s.value else TOP
            self.returns.append(u)
        elif isinstance(s, ast.If):
            self._infer(s.test, env)
            e1, e2 = dict(env), dict(env)
            for b in s.body:
                self._stmt(b, e1)
            for b in s.orelse:
                self._stmt(b, e2)
            self._merge(env, e1, e2)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            it = self._infer(s.iter, env)
            e1 = dict(env)
            self._bind_target(s.target, it, e1)
            for b in s.body:
                self._stmt(b, e1)
            for b in s.orelse:
                self._stmt(b, e1)
            self._merge(env, e1, env)
        elif isinstance(s, ast.While):
            self._infer(s.test, env)
            e1 = dict(env)
            for b in s.body:
                self._stmt(b, e1)
            for b in s.orelse:
                self._stmt(b, e1)
            self._merge(env, e1, env)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                self._infer(item.context_expr, env)
            for b in s.body:
                self._stmt(b, env)
        elif isinstance(s, ast.Try):
            for b in s.body + s.orelse + s.finalbody:
                self._stmt(b, env)
            for h in s.handlers:
                for b in h.body:
                    self._stmt(b, env)
        elif isinstance(s, ast.Expr):
            self._infer(s.value, env)
        elif isinstance(s, ast.Assert):
            self._infer(s.test, env)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            qual = f"{self.summary.qualname}.<locals>.{s.name}"
            sub = self.mod._initial_summary(s, qual)
            interp = _FnInterp(self.mod, s, sub, self.report,
                               outer_env=env)
            sub.ret_inferred = interp.run()
            if sub.ret_declared is None:
                sub.ret = sub.ret_inferred
            self.mod.summaries.setdefault(s.name, sub)
        elif isinstance(s, ast.Raise):
            if s.exc is not None:
                self._infer(s.exc, env)
        # Pass/Break/Continue/Import/Global/Nonlocal/Delete: nothing

    def _merge(self, env: dict, e1: dict, e2: dict) -> None:
        for k in set(e1) | set(e2):
            if k in e1 and k in e2:
                env[k] = join(e1[k], e2[k])
            else:
                env[k] = e1.get(k, e2.get(k, TOP))

    def _assign(self, s: ast.stmt, env: dict) -> None:
        declared = self.mod.line_units.get(s.lineno)
        if isinstance(s, ast.AugAssign):
            self._aug_assign(s, env)
            return
        value = s.value
        u = self._infer(value, env) if value is not None else TOP
        if declared is not None:
            if isinstance(u, Unit) and isinstance(declared, Unit) \
                    and u.concrete and declared.concrete \
                    and not u.dimensionless and u.dims != declared.dims:
                self._flag(s, f"value has unit '{u}' but is annotated "
                              f"'# unit: {declared}'")
            u = declared
        targets = s.targets if isinstance(s, ast.Assign) else [s.target]
        for t in targets:
            self._bind_target(t, u, env, check=declared is None)

    def _bind_target(self, t: ast.AST, u: AbstractUnit, env: dict,
                     check: bool = False) -> None:
        if isinstance(t, ast.Name):
            if check:
                self._check_seed(t, t.id, u)
            if isinstance(u, Unit) and u.is_any:
                # bare-literal init (x = 0): the name's seed is more
                # informative than the polymorphic literal
                seed = seed_unit(t.id)
                if seed is not None:
                    u = seed
            env[t.id] = u
        elif isinstance(t, (ast.Tuple, ast.List)):
            elts = u.elts if isinstance(u, TupleUnit) \
                and len(u.elts) == len(t.elts) else [TOP] * len(t.elts)
            for sub, su in zip(t.elts, elts):
                self._bind_target(sub, su, env)
        elif isinstance(t, ast.Subscript):
            cont = self._infer(t.value, env)
            if isinstance(cont, Unit) and isinstance(u, Unit) \
                    and cont.concrete and u.concrete \
                    and not u.dimensionless and cont.dims != u.dims:
                self._flag(t, f"storing '{u}' into a container of "
                              f"'{cont}'")
        elif isinstance(t, ast.Attribute):
            if check:
                self._check_seed(t, t.attr, u)
        elif isinstance(t, ast.Starred):
            self._bind_target(t.value, TOP, env)

    def _check_seed(self, node: ast.AST, name: str, u: AbstractUnit) -> None:
        seed = seed_unit(name)
        if seed is None or not isinstance(u, Unit) \
                or not isinstance(seed, Unit):
            return
        if u.concrete and seed.concrete and not u.dimensionless \
                and not seed.dimensionless and u.dims != seed.dims:
            self._flag(node,
                       f"assigning '{u}' to '{name}', whose name "
                       f"suggests '{seed}' (annotate with # unit: if "
                       "intentional)")

    def _aug_assign(self, s: ast.AugAssign, env: dict) -> None:
        r = self._infer(s.value, env)
        t = s.target
        if isinstance(t, ast.Name):
            l = self._name_unit(t.id)
        elif isinstance(t, ast.Attribute):
            l = self.mod.attr_unit(t.attr) or TOP
        else:
            l = self._infer(t.value, env) if isinstance(t, ast.Subscript) \
                else TOP
        if isinstance(s.op, (ast.Add, ast.Sub)):
            out = self._check_add(s, l, r, "augmented assignment")
            if isinstance(t, ast.Name):
                env[t.id] = out
        elif isinstance(t, ast.Name) and isinstance(l, Unit) \
                and isinstance(r, Unit):
            if isinstance(s.op, ast.Mult):
                env[t.id] = l.mul(r)
            elif isinstance(s.op, (ast.Div, ast.FloorDiv)):
                env[t.id] = l.div(r)

    def _check_add(self, node: ast.AST, l: AbstractUnit, r: AbstractUnit,
                   what: str) -> AbstractUnit:
        if not compatible(l, r):
            self._flag(node, f"unit mismatch in {what}: '{l}' vs '{r}'")
            return TOP
        return join(l, r) if not (isinstance(l, Unit) and l.is_any
                                  and isinstance(r, Unit) and r.is_any) \
            else ANY

    # -- expressions -------------------------------------------------------
    def _infer(self, e: Optional[ast.AST], env: dict) -> AbstractUnit:
        if e is None:
            return TOP
        if isinstance(e, ast.Constant):
            return ANY if isinstance(e.value, (int, float)) \
                and not isinstance(e.value, bool) else ANY
        if isinstance(e, ast.Name):
            return self._name_unit(e.id)
        if isinstance(e, ast.Attribute):
            self._infer(e.value, env)
            u = self.mod.attr_unit(e.attr)
            return u if u is not None else TOP
        if isinstance(e, ast.BinOp):
            return self._binop(e, env)
        if isinstance(e, ast.UnaryOp):
            return self._infer(e.operand, env)
        if isinstance(e, ast.Compare):
            return self._compare(e, env)
        if isinstance(e, ast.BoolOp):
            out: AbstractUnit = TOP
            for i, v in enumerate(e.values):
                u = self._infer(v, env)
                out = u if i == 0 else join(out, u)
            return out
        if isinstance(e, ast.IfExp):
            self._infer(e.test, env)
            return join(self._infer(e.body, env),
                        self._infer(e.orelse, env))
        if isinstance(e, ast.Call):
            return self._call(e, env)
        if isinstance(e, ast.Subscript):
            base = self._infer(e.value, env)
            self._infer(e.slice, env)
            if isinstance(base, TupleUnit):
                idx = e.slice
                if isinstance(idx, ast.Constant) \
                        and isinstance(idx.value, int) \
                        and -len(base.elts) <= idx.value < len(base.elts):
                    return base.elts[idx.value]
                out: AbstractUnit = base.elts[0] if base.elts else TOP
                for el in base.elts[1:]:
                    out = join(out, el)
                return out
            return base        # container ≡ element unit
        if isinstance(e, ast.Tuple):
            return TupleUnit([self._infer(x, env) for x in e.elts])
        if isinstance(e, (ast.List, ast.Set)):
            out = TOP
            for i, x in enumerate(e.elts):
                u = self._infer(x, env)
                out = u if i == 0 else join(out, u)
            return out
        if isinstance(e, ast.Dict):
            out = TOP
            for i, v in enumerate(e.values):
                if v is None:
                    continue
                u = self._infer(v, env)
                out = u if i == 0 else join(out, u)
            return out
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            sub = dict(env)
            for gen in e.generators:
                self._bind_target(gen.target, self._infer(gen.iter, sub),
                                  sub)
            return self._infer(e.elt, sub)
        if isinstance(e, ast.DictComp):
            sub = dict(env)
            for gen in e.generators:
                self._bind_target(gen.target, self._infer(gen.iter, sub),
                                  sub)
            return self._infer(e.value, sub)
        if isinstance(e, ast.NamedExpr):
            u = self._infer(e.value, env)
            self._bind_target(e.target, u, env)
            return u
        if isinstance(e, ast.Starred):
            return self._infer(e.value, env)
        if isinstance(e, (ast.JoinedStr, ast.FormattedValue, ast.Lambda)):
            return TOP
        if isinstance(e, ast.Slice):
            for part in (e.lower, e.upper, e.step):
                if part is not None:
                    self._infer(part, env)
            return TOP
        return TOP

    def _conv_literal(self, e: ast.AST) -> Optional[Unit]:
        node = e
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            node = node.operand
        if isinstance(node, ast.Constant) \
                and isinstance(node.value, (int, float)) \
                and not isinstance(node.value, bool):
            conv = CONVERSIONS.get(float(node.value))
            if conv is not None:
                return _u(conv)
        return None

    def _binop(self, e: ast.BinOp, env: dict) -> AbstractUnit:
        l = self._infer(e.left, env)
        r = self._infer(e.right, env)
        if not isinstance(l, Unit) or not isinstance(r, Unit):
            return TOP
        if isinstance(e.op, (ast.Add, ast.Sub)):
            return self._check_add(
                e, l, r, "+" if isinstance(e.op, ast.Add) else "-")
        if isinstance(e.op, (ast.Mult, ast.Div, ast.FloorDiv)):
            lc, rc = self._conv_literal(e.left), self._conv_literal(e.right)
            if rc is not None and l.concrete and self._shares(l, rc):
                r = rc
            elif lc is not None and r.concrete and self._shares(r, lc):
                l = lc
            return l.mul(r) if isinstance(e.op, ast.Mult) else l.div(r)
        if isinstance(e.op, ast.Mod):
            return l
        if isinstance(e.op, ast.Pow):
            if isinstance(e.right, ast.Constant) \
                    and isinstance(e.right.value, int):
                return l.pow(e.right.value)
            return l if l.dimensionless or not l.concrete else TOP
        if isinstance(e.op, ast.MatMult):
            return l.mul(r)
        return TOP

    @staticmethod
    def _shares(u: Unit, conv: Unit) -> bool:
        dims = {k for k, _ in u.dims}
        return any(k in dims for k, _ in conv.dims)

    def _compare(self, e: ast.Compare, env: dict) -> AbstractUnit:
        ops = [self._infer(x, env)
               for x in [e.left] + list(e.comparators)]
        for i, op in enumerate(e.ops):
            if isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE,
                               ast.Eq, ast.NotEq)):
                if not compatible(ops[i], ops[i + 1]):
                    self._flag(e, "unit mismatch in comparison: "
                                  f"'{ops[i]}' vs '{ops[i + 1]}'")
        return DIMLESS

    def _call(self, e: ast.Call, env: dict) -> AbstractUnit:
        arg_units = [self._infer(a, env) for a in e.args]
        kw_units = {kw.arg: self._infer(kw.value, env)
                    for kw in e.keywords if kw.arg}
        q = self.mod.imports.qualname(e.func)
        recv_u: Optional[AbstractUnit] = None
        attr = None
        if isinstance(e.func, ast.Attribute):
            attr = e.func.attr
            recv_u = self._infer(e.func.value, env)
        tail = (q or attr or "").rsplit(".", 1)[-1]
        if q in _MINMAX_FNS or tail in ("minimum", "maximum") \
                and q in _MINMAX_FNS:
            return self._minmax(e, arg_units)
        if tail in ("min", "max") and q in _MINMAX_FNS:
            return self._minmax(e, arg_units)
        if q in _ISCLOSE_FNS or (attr in ("isclose", "allclose")):
            if len(arg_units) >= 2 and not compatible(arg_units[0],
                                                      arg_units[1]):
                self._flag(e, "unit mismatch in closeness check: "
                              f"'{arg_units[0]}' vs '{arg_units[1]}'")
            return DIMLESS
        if q in _DIMLESS_FNS:
            return DIMLESS
        if q == "numpy.clip" or attr == "clip":
            units = ([recv_u] if attr == "clip" and recv_u is not None
                     else []) + arg_units
            out = units[0] if units else TOP
            for u in units[1:]:
                if not compatible(out, u):
                    self._flag(e, f"unit mismatch in clip: '{out}' vs "
                                  f"'{u}'")
                out = join(out, u)
            return out
        if q in ("numpy.divide", "numpy.true_divide") \
                and len(arg_units) >= 2:
            a, b = arg_units[0], arg_units[1]
            if isinstance(a, Unit) and isinstance(b, Unit):
                return a.div(b)
            return TOP
        if q == "numpy.dot" and len(arg_units) == 2 \
                and isinstance(arg_units[0], Unit) \
                and isinstance(arg_units[1], Unit):
            return arg_units[0].mul(arg_units[1])
        if q in _PASSTHROUGH_FNS:
            return arg_units[0] if arg_units else TOP
        if attr in _PASSTHROUGH_METHODS and recv_u is not None:
            return recv_u
        if q == "enumerate":
            return TupleUnit([DIMLESS,
                              arg_units[0] if arg_units else TOP])
        if q == "zip":
            return TupleUnit(arg_units)
        if q == "range":
            return DIMLESS
        # user function: summary lookup (local first, then project)
        callee = None
        if isinstance(e.func, ast.Name):
            callee = self.mod.lookup_callee(e.func.id)
        elif attr is not None:
            callee = self.mod.lookup_callee(attr)
        if callee is not None:
            self._check_args(e, callee, arg_units, kw_units)
            return callee.ret
        if q is not None:
            u = seed_unit(q.rsplit(".", 1)[-1])
            if u is not None:
                return u
        return TOP

    def _minmax(self, e: ast.Call, arg_units: list) -> AbstractUnit:
        if len(arg_units) < 2:        # min(xs) over one iterable
            return arg_units[0] if arg_units else TOP
        out = arg_units[0]
        for u in arg_units[1:]:
            if not compatible(out, u):
                self._flag(e, f"unit mismatch in min/max: '{out}' vs "
                              f"'{u}'")
            out = join(out, u)
        return out

    def _check_args(self, e: ast.Call, callee: FuncSummary,
                    arg_units: list, kw_units: dict) -> None:
        pairs = list(zip(callee.param_order, arg_units))
        pairs += [(k, u) for k, u in kw_units.items()
                  if k in callee.params]
        for pname, got in pairs:
            want = callee.params.get(pname, TOP)
            if isinstance(want, Unit) and isinstance(got, Unit) \
                    and want.concrete and got.concrete \
                    and not want.dimensionless and not got.dimensionless \
                    and want.dims != got.dims:
                self._flag(e, f"argument '{pname}' of "
                              f"{callee.qualname}() expects '{want}', "
                              f"got '{got}'")


# ---------------------------------------------------------------------------
# cross-module summaries
# ---------------------------------------------------------------------------

#: modules whose function summaries feed interprocedural resolution
PROJECT_MODULES = (
    "repro/core/accelerators.py",
    "repro/core/workload.py",
    "repro/core/profiler.py",
    "repro/core/engine_model.py",
    "repro/core/loadmatrix.py",
    "repro/core/simulator.py",
    "repro/serving/kv_cache.py",
    "repro/regions/catalog.py",
    "repro/regions/problem.py",
    "repro/regions/allocator.py",
    "repro/regions/autoscaler.py",
    "repro/orchestrator/timeline.py",
    "repro/orchestrator/orchestrator.py",
    "repro/orchestrator/regional.py",
)

_SRC_ROOT = Path(__file__).resolve().parents[1]      # .../src/repro
_project_cache: dict = {}


def _module_path(rel: str) -> Path:
    return _SRC_ROOT / rel.split("repro/", 1)[1]


def project_summaries(exclude_rel: Optional[str] = None
                      ) -> dict[str, FuncSummary]:
    """Two-pass global summary table over :data:`PROJECT_MODULES`.

    ``exclude_rel`` omits one module (the file currently being linted —
    its in-flight source, not the on-disk copy, is authoritative).
    Cached per (mtimes, exclude) key."""
    paths = [(rel, _module_path(rel)) for rel in PROJECT_MODULES
             if rel != exclude_rel]
    paths = [(rel, p) for rel, p in paths if p.exists()]
    key = (exclude_rel, tuple(p.stat().st_mtime_ns for _, p in paths))
    if key in _project_cache:
        return _project_cache[key]
    table: dict[str, FuncSummary] = {}
    for _pass in range(2):
        for rel, p in paths:
            try:
                mod = ModuleUnits(p.read_text(), rel, external=table)
            except SyntaxError:
                continue
            for name, s in mod.summaries.items():
                prev = table.get(name)
                if prev is not None and "." not in name \
                        and prev.qualname != s.qualname \
                        and prev.ret != s.ret:
                    table[name] = FuncSummary(name, name)   # ambiguous: TOP
                else:
                    table[name] = s
    if len(_project_cache) > 64:     # bound growth across mtime churn
        _project_cache.clear()
    _project_cache[key] = table
    return table


# ---------------------------------------------------------------------------
# param-mutation aliasing analysis
# ---------------------------------------------------------------------------

#: functions whose contract is in-place mutation of caller arrays
#: ("<rel>::<qualname>"); the arrays passed in ARE the arrays returned.
SANCTIONED_MUTATORS = {
    # PR 8's fast-path contract: greedy/local-search mutate assign/load/
    # counts in place so callers keep their own arrays (the hot-fix bug
    # was precisely a rebind that broke this).
    "repro/core/ilp.py::_local_search",
    "repro/core/ilp.py::_local_search_reference",
}

_ND_MUTATOR_METHODS = {"sort", "fill", "partition", "put", "itemset",
                       "resize", "setfield", "byteswap", "append",
                       "extend", "insert", "clear", "update"}
_FUNC_MUTATORS = {"numpy.copyto", "numpy.put", "numpy.place",
                  "numpy.putmask", "numpy.fill_diagonal",
                  "random.shuffle"}
# receiver methods that return views of the receiver (alias-preserving)
_VIEW_METHODS = {"view", "reshape", "ravel", "transpose", "swapaxes",
                 "squeeze"}
_VIEW_FUNCS = {"numpy.asarray", "numpy.ascontiguousarray",
               "numpy.atleast_1d", "numpy.ravel", "numpy.transpose",
               "numpy.broadcast_to"}


@dataclasses.dataclass
class Mutation:
    node: ast.AST
    param: str
    what: str


def _annotation_is_arrayish(a: ast.arg) -> bool:
    if a.annotation is None:
        return False
    try:
        text = ast.unparse(a.annotation)
    except Exception:                                  # pragma: no cover
        return False
    return "ndarray" in text or "array" in text


def param_mutations(fn: ast.AST, imports: _Imports, rel: str,
                    qualname: Optional[str] = None) -> list[Mutation]:
    """In-place mutations of parameter-reachable objects in ``fn``."""
    qual = qualname or fn.name
    if f"{rel}::{qual}" in SANCTIONED_MUTATORS \
            or f"{rel}::{fn.name}" in SANCTIONED_MUTATORS:
        return []
    # *args tuples and **kwargs dicts are freshly constructed per call —
    # mutating them never aliases caller state, so only named params count
    args = list(getattr(fn.args, "posonlyargs", [])) + fn.args.args \
        + fn.args.kwonlyargs
    params = [a for a in args if a.arg not in ("self", "cls")]
    aliases = {a.arg for a in params}
    arrayish = {a.arg for a in params if _annotation_is_arrayish(a)}
    out: list[Mutation] = []
    _walk_mutations(fn.body, aliases, arrayish,
                    {a: a for a in aliases}, imports, out)
    return out


def _alias_root(e: ast.AST, aliases: set) -> Optional[str]:
    """Param name an expression aliases, or None."""
    if isinstance(e, ast.Name):
        return e.id if e.id in aliases else None
    if isinstance(e, (ast.Attribute, ast.Subscript, ast.Starred)):
        return _alias_root(e.value, aliases)
    if isinstance(e, ast.Call):
        q = None
        if isinstance(e.func, ast.Attribute) \
                and e.func.attr in _VIEW_METHODS:
            return _alias_root(e.func.value, aliases)
        if isinstance(e.func, (ast.Name, ast.Attribute)):
            q = _qual_of(e.func)
        if q in _VIEW_FUNCS and e.args:
            return _alias_root(e.args[0], aliases)
        return None
    if isinstance(e, ast.IfExp):
        return _alias_root(e.body, aliases) \
            or _alias_root(e.orelse, aliases)
    if isinstance(e, ast.NamedExpr):
        return _alias_root(e.value, aliases)
    return None


_qual_imports: Optional[_Imports] = None


def _qual_of(node: ast.AST) -> Optional[str]:
    if _qual_imports is not None:
        return _qual_imports.qualname(node)
    return None


def _walk_mutations(body: list, aliases: set, arrayish: set,
                    origin: dict, imports: _Imports,
                    out: list[Mutation]) -> None:
    global _qual_imports
    _qual_imports = imports
    for s in body:
        _mut_stmt(s, aliases, arrayish, origin, imports, out)


def _origin_of(name: Optional[str], origin: dict) -> str:
    return origin.get(name, name) or "?"


def _mut_stmt(s: ast.stmt, aliases: set, arrayish: set, origin: dict,
              imports: _Imports, out: list[Mutation]) -> None:
    if isinstance(s, ast.Assign):
        _mut_expr(s.value, aliases, origin, imports, out)
        src = _alias_root(s.value, aliases)
        for t in s.targets:
            if isinstance(t, ast.Subscript):
                root = _alias_root(t.value, aliases)
                if root is not None:
                    out.append(Mutation(
                        t, _origin_of(root, origin),
                        "in-place subscript store"))
                _mut_expr(t.value, aliases, origin, imports, out)
            elif isinstance(t, ast.Name):
                if src is not None:
                    aliases.add(t.id)
                    origin[t.id] = _origin_of(src, origin)
                    if src in arrayish or _origin_of(src, origin) \
                            in arrayish:
                        arrayish.add(t.id)
                else:
                    aliases.discard(t.id)
                    arrayish.discard(t.id)
                    origin.pop(t.id, None)
            elif isinstance(t, (ast.Tuple, ast.List)):
                for el in t.elts:
                    if isinstance(el, ast.Name):
                        aliases.discard(el.id)
                        arrayish.discard(el.id)
    elif isinstance(s, ast.AugAssign):
        _mut_expr(s.value, aliases, origin, imports, out)
        t = s.target
        if isinstance(t, ast.Subscript):
            root = _alias_root(t.value, aliases)
            if root is not None:
                out.append(Mutation(t, _origin_of(root, origin),
                                    "augmented subscript assign"))
        elif isinstance(t, ast.Name) and t.id in aliases \
                and (t.id in arrayish
                     or _origin_of(t.id, origin) in arrayish):
            out.append(Mutation(t, _origin_of(t.id, origin),
                                "augmented assign on ndarray "
                                "(in-place via __iadd__)"))
        elif isinstance(t, ast.Attribute):
            root = _alias_root(t.value, aliases)
            if root is not None:
                out.append(Mutation(t, _origin_of(root, origin),
                                    "augmented attribute assign"))
    elif isinstance(s, (ast.For, ast.AsyncFor)):
        _mut_expr(s.iter, aliases, origin, imports, out)
        if isinstance(s.target, ast.Name):
            aliases.discard(s.target.id)
            arrayish.discard(s.target.id)
        for b in s.body + s.orelse:
            _mut_stmt(b, aliases, arrayish, origin, imports, out)
    elif isinstance(s, (ast.If, ast.While)):
        _mut_expr(s.test, aliases, origin, imports, out)
        for b in s.body + s.orelse:
            _mut_stmt(b, aliases, arrayish, origin, imports, out)
    elif isinstance(s, (ast.With, ast.AsyncWith)):
        for b in s.body:
            _mut_stmt(b, aliases, arrayish, origin, imports, out)
    elif isinstance(s, ast.Try):
        for b in s.body + s.orelse + s.finalbody:
            _mut_stmt(b, aliases, arrayish, origin, imports, out)
        for h in s.handlers:
            for b in h.body:
                _mut_stmt(b, aliases, arrayish, origin, imports, out)
    elif isinstance(s, (ast.Expr, ast.Return, ast.Assert, ast.Raise)):
        for v in ast.iter_child_nodes(s):
            _mut_expr(v, aliases, origin, imports, out)
    elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
        inner_args = {a.arg for a in
                      list(getattr(s.args, "posonlyargs", []))
                      + s.args.args + s.args.kwonlyargs}
        sub_aliases = {a for a in aliases if a not in inner_args}
        sub_array = {a for a in arrayish if a not in inner_args}
        _walk_mutations(s.body, sub_aliases, sub_array, dict(origin),
                        imports, out)


def _mut_expr(e: ast.AST, aliases: set, origin: dict,
              imports: _Imports, out: list[Mutation]) -> None:
    for node in ast.walk(e):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _ND_MUTATOR_METHODS:
            root = _alias_root(node.func.value, aliases)
            if root is not None:
                out.append(Mutation(
                    node, _origin_of(root, origin),
                    f".{node.func.attr}() mutates in place"))
        q = imports.qualname(node.func)
        if q in _FUNC_MUTATORS and node.args:
            root = _alias_root(node.args[0], aliases)
            if root is not None:
                out.append(Mutation(node, _origin_of(root, origin),
                                    f"{q}() mutates its first argument"))
        for kw in node.keywords:
            if kw.arg == "out":
                root = _alias_root(kw.value, aliases)
                if root is not None:
                    out.append(Mutation(
                        node, _origin_of(root, origin),
                        "out= kwarg writes into parameter array"))


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def infer_module(source: str, rel: str,
                 external: Optional[dict[str, FuncSummary]] = None
                 ) -> ModuleUnits:
    """Analyze one module's units; external defaults to no cross-module
    summaries (pass :func:`project_summaries` output for full flow)."""
    return ModuleUnits(source, rel, external=external)
