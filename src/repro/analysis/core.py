"""Lint framework: single-AST-walk rule engine, pragmas, baseline.

Design goals, in order:

1. **Zero dependencies.**  Everything here is stdlib ``ast``/``re``/
   ``json``/``hashlib``.  The linter guards the environment's invariants;
   it must not change the environment to do so.
2. **One walk per file.**  Rules declare the node types they care about
   (``node_types``); the engine parses each file once and dispatches each
   node to the interested rules.  Adding a rule never adds a traversal.
3. **Escape hatches that leave a paper trail.**  A violation can be
   suppressed inline with ``# lint: allow[rule-name]`` on the offending
   line or the line directly above (comma-separate several rules,
   ``allow[*]`` suppresses everything) — the pragma sits next to the code
   it excuses, so review sees both.  Pre-existing violations can be
   grandfathered via a baseline file (``--write-baseline``) whose entries
   are fingerprints of (path, rule, stripped line text): the fingerprint
   survives pure line-number drift but dies when the offending line is
   edited, forcing a fresh look.

Rules subclass :class:`Rule` and register with the :func:`rule`
decorator.  A fresh rule instance is created per file, so instance
attributes are per-file state; rules that need to see the whole file
(e.g. decorator-conditional checks) collect candidates in ``visit`` and
emit in ``finish``.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from collections import Counter
from pathlib import Path
from typing import Iterable, Optional, Sequence, Type

PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\[([^\]]+)\]")

#: rule-name -> Rule subclass; populated by the @rule decorator.
RULES: dict[str, Type["Rule"]] = {}


def rule(cls: Type["Rule"]) -> Type["Rule"]:
    """Class decorator: register a Rule subclass under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"rule class {cls.__name__} has no name")
    if cls.name in RULES:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    RULES[cls.name] = cls
    return cls


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str          # repo-style posix path, e.g. "repro/core/ilp.py"
    line: int
    col: int
    message: str
    line_text: str = ""

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def fingerprint(self) -> str:
        """Baseline identity: survives line-number drift, dies on edit."""
        key = f"{self.path}|{self.rule}|{self.line_text.strip()}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]


class Rule:
    """Base class for lint rules.

    Subclasses set ``name`` (kebab-case id used in pragmas/CLI),
    ``summary`` (one line), ``explain`` (the ``--explain`` text — doubles
    as the rule's documentation), and ``node_types`` (ast classes
    dispatched to ``visit``).  ``applies_to(rel)`` scopes the rule to a
    subset of the tree; out-of-scope files never instantiate the rule.
    """

    name: str = ""
    summary: str = ""
    explain: str = ""
    node_types: tuple = ()
    #: rules that inspect other rules' outcomes (dead-pragma) finish last
    runs_last: bool = False

    def applies_to(self, rel: str) -> bool:
        return True

    def visit(self, node: ast.AST, ctx: "FileLint") -> None:
        pass

    def finish(self, ctx: "FileLint") -> None:
        pass


class FileLint:
    """Per-file lint context: source, tree, import aliases, pragmas.

    Rules receive this as ``ctx``.  Useful surface:

    - ``ctx.qualname(expr)``: dotted name of a Name/Attribute chain with
      import aliases resolved (``pc()`` after ``from time import
      perf_counter as pc`` resolves to ``"time.perf_counter"``); ``None``
      for non-name expressions.
    - ``ctx.func_stack``: enclosing FunctionDef/Lambda nodes, outermost
      first.
    - ``ctx.report(rule, node, message)``: file a violation unless a
      pragma on the node's line (or the line above) allows it.
    """

    def __init__(self, rel: str, source: str,
                 rules: Sequence[Rule],
                 selected: Optional[set] = None) -> None:
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self.rules = list(rules)
        #: rule names requested for this run; None means the full set.
        #: dead-pragma uses this to avoid calling a pragma dead when the
        #: rule it suppresses simply wasn't selected.
        self.selected = selected
        self.violations: list[Violation] = []
        self.func_stack: list[ast.AST] = []
        # import-alias tables, filled during the walk (imports precede use)
        self.aliases: dict[str, str] = {}        # "np" -> "numpy"
        self.from_imports: dict[str, str] = {}   # "pc" -> "time.perf_counter"
        self._pragmas = self._parse_pragmas()
        #: pragma line -> tags that actually suppressed something
        self.pragma_hits: dict[int, set[str]] = {}
        self._dispatch: dict[type, list[Rule]] = {}
        for r in self.rules:
            for t in r.node_types:
                self._dispatch.setdefault(t, []).append(r)

    # ---- pragmas ---------------------------------------------------------
    def _parse_pragmas(self) -> dict[int, set[str]]:
        out: dict[int, set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = PRAGMA_RE.search(line)
            if m:
                out[i] = {p.strip() for p in m.group(1).split(",") if p.strip()}
        return out

    def allowed(self, rule_name: str, lineno: int) -> bool:
        tags = self._pragmas.get(lineno)
        if tags and (rule_name in tags or "*" in tags):
            self.pragma_hits.setdefault(lineno, set()).add(
                rule_name if rule_name in tags else "*")
            return True
        # the line above counts only as a *standalone* pragma comment —
        # a trailing pragma on code never spills onto the next line
        above = self._pragmas.get(lineno - 1)
        if above and self.line_text(lineno - 1).strip().startswith("#"):
            if rule_name in above or "*" in above:
                self.pragma_hits.setdefault(lineno - 1, set()).add(
                    rule_name if rule_name in above else "*")
                return True
        return False

    # ---- rule surface ----------------------------------------------------
    def qualname(self, node: ast.AST) -> Optional[str]:
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = node.id
        if base in self.aliases:
            parts.append(self.aliases[base])
        elif base in self.from_imports:
            parts.append(self.from_imports[base])
        else:
            parts.append(base)
        return ".".join(reversed(parts))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def report(self, r: Rule, node: ast.AST, message: str,
               force: bool = False) -> None:
        """File a violation.  ``force`` bypasses pragma suppression —
        used by dead-pragma on ``allow[*]`` lines, which would otherwise
        self-suppress their own deadness report."""
        lineno = getattr(node, "lineno", 1)
        if not force and self.allowed(r.name, lineno):
            return
        self.violations.append(Violation(
            rule=r.name, path=self.rel, line=lineno,
            col=getattr(node, "col_offset", 0) + 1, message=message,
            line_text=self.line_text(lineno)))

    # ---- the walk --------------------------------------------------------
    def run(self) -> list[Violation]:
        self._walk(self.tree)
        for r in self.rules:
            if not r.runs_last:
                r.finish(self)
        for r in self.rules:
            if r.runs_last:
                r.finish(self)
        self.violations.sort(key=lambda v: (v.line, v.col, v.rule))
        return self.violations

    def _record_import(self, node: ast.AST) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    self.aliases[a.asname] = a.name
                else:
                    # "import a.b.c" binds "a" to package "a"
                    self.aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                self.from_imports[a.asname or a.name] = \
                    f"{node.module}.{a.name}"

    def _walk(self, node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            self._handle(child)

    def _handle(self, node: ast.AST) -> None:
        t = type(node)
        if t in (ast.Import, ast.ImportFrom):
            self._record_import(node)
        for r in self._dispatch.get(t, ()):
            r.visit(node, self)
        if t in (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda):
            self.func_stack.append(node)
            self._walk(node)
            self.func_stack.pop()
        else:
            self._walk(node)


# ---- entry points --------------------------------------------------------

def _make_rules(rel: str,
                rule_names: Optional[Sequence[str]] = None) -> list[Rule]:
    names = list(rule_names) if rule_names is not None else sorted(RULES)
    out = []
    for n in names:
        if n not in RULES:
            raise KeyError(f"unknown rule {n!r} (see --list-rules)")
        r = RULES[n]()
        if r.applies_to(rel):
            out.append(r)
    return out


def lint_source(source: str, rel: str,
                rule_names: Optional[Sequence[str]] = None) -> list[Violation]:
    """Lint one source string as if it lived at repo path ``rel``."""
    rules = _make_rules(rel, rule_names)
    if not rules:
        return []
    selected = set(rule_names) if rule_names is not None else None
    return FileLint(rel, source, rules, selected=selected).run()


def repo_rel(path: Path) -> str:
    """Repo-style path: suffix starting at the last ``repro`` component
    (or ``tests``/``benchmarks`` for the top-level trees)."""
    parts = list(Path(path).resolve().parts)
    for anchor in ("repro", "tests", "benchmarks"):
        if anchor in parts:
            i = len(parts) - 1 - parts[::-1].index(anchor)
            return "/".join(parts[i:])
    return Path(path).name


def iter_py_files(paths: Iterable[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    return files


@dataclasses.dataclass
class LintResult:
    violations: list[Violation]          # after baseline filtering
    n_files: int
    n_parse_errors: int = 0
    baseline_filtered: int = 0
    #: baseline entries whose fingerprint matched nothing this run
    stale_baseline: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations and not self.n_parse_errors


def load_baseline_entries(path: Path) -> list:
    """Full baseline entries (fingerprint/rule/path) for staleness checks."""
    data = json.loads(Path(path).read_text())
    return list(data.get("entries", []))


def load_baseline(path: Path) -> Counter:
    return Counter(e["fingerprint"] for e in load_baseline_entries(path))


def write_baseline(violations: Sequence[Violation], path: Path) -> None:
    entries = [{"fingerprint": v.fingerprint(), "rule": v.rule,
                "path": v.path} for v in violations]
    Path(path).write_text(json.dumps(
        {"version": 1, "entries": entries}, indent=1) + "\n")


def apply_baseline(violations: Sequence[Violation],
                   baseline: Counter) -> tuple[list[Violation], int]:
    """Multiset filtering: each baseline fingerprint absorbs one match."""
    budget = Counter(baseline)
    kept, dropped = [], 0
    for v in violations:
        fp = v.fingerprint()
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
            dropped += 1
        else:
            kept.append(v)
    return kept, dropped


# the linter's own rule definitions embed the very string patterns the
# rules hunt for, so the package never lints itself
SELF_PREFIX = "repro/analysis/"


def lint_paths(paths: Iterable[Path],
               rule_names: Optional[Sequence[str]] = None,
               baseline: Optional[Counter] = None,
               baseline_entries: Optional[Sequence[dict]] = None
               ) -> LintResult:
    if baseline is None and baseline_entries is not None:
        baseline = Counter(e.get("fingerprint")
                           for e in baseline_entries)
    violations: list[Violation] = []
    n_files = n_err = 0
    walked: set[str] = set()
    for f in iter_py_files(paths):
        rel = repo_rel(f)
        if rel.startswith(SELF_PREFIX):
            continue
        n_files += 1
        walked.add(rel)
        try:
            src = f.read_text()
            violations.extend(lint_source(src, rel, rule_names))
        except SyntaxError as e:
            n_err += 1
            violations.append(Violation(
                rule="parse-error", path=rel, line=e.lineno or 1, col=1,
                message=f"could not parse: {e.msg}"))
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    # baseline staleness: entries that matched nothing this run, judged
    # only for walked files and selected rules (otherwise undecidable)
    stale: list = []
    if baseline_entries:
        leftover = Counter(baseline) \
            - Counter(v.fingerprint() for v in violations)
        for e in baseline_entries:
            fp = e.get("fingerprint")
            if leftover.get(fp, 0) > 0 and e.get("path") in walked \
                    and (rule_names is None
                         or e.get("rule") in rule_names):
                leftover[fp] -= 1
                stale.append(e)
    dropped = 0
    if baseline:
        violations, dropped = apply_baseline(violations, baseline)
    dead_pragma_on = rule_names is None or "dead-pragma" in rule_names
    if stale and dead_pragma_on:
        for e in stale:
            violations.append(Violation(
                rule="dead-pragma", path=e.get("path", "?"), line=0, col=1,
                message=f"stale baseline fingerprint {e.get('fingerprint')} "
                        f"({e.get('rule')}) no longer matches any "
                        "violation; run --prune-baseline"))
        violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return LintResult(violations, n_files, n_err, dropped,
                      stale_baseline=stale)
