"""`repro.analysis`: AST-based invariant linter for the solver/simulator
contracts.

The repo's headline numbers rest on invariants nothing else enforces
mechanically: exact cost parity between the four solver layers, a
deterministic sim-clock-pure simulator, the single half-open bucketing
rule, inf (never 1e9) infeasibility masks, canonical pool-name
composition, seeded RNG everywhere, bounded metric label cardinality, and
pure jit/pallas kernel bodies.  Each rule here encodes one of those
contracts as a single-AST-walk check; the CLI (``python -m
repro.analysis``) runs them over the source tree, honours per-line
``# lint: allow[rule]`` pragmas and a grandfathering baseline file, and
exits non-zero under ``--strict`` so CI can gate on them.

Everything is stdlib-only (``ast`` + ``re`` + ``json``): the linter adds
no dependency to the environment it protects.
"""
from .core import (FileLint, LintResult, Rule, RULES, Violation,
                   iter_py_files, lint_paths, lint_source, load_baseline,
                   load_baseline_entries, write_baseline, rule)
from . import rules as _rules  # noqa: F401  (registers the rule set)
from . import dataflow  # noqa: F401  (units/aliasing engine)

__all__ = [
    "FileLint", "LintResult", "Rule", "RULES", "Violation", "dataflow",
    "iter_py_files", "lint_paths", "lint_source", "load_baseline",
    "load_baseline_entries", "write_baseline", "rule",
]
