"""The repo-specific rule set.

Each rule encodes one invariant the solver/simulator stack depends on
but that nothing else enforces mechanically.  The ``explain`` strings
are the rule documentation (``python -m repro.analysis --explain RULE``);
keep them the source of truth when changing a rule's scope.
"""
from __future__ import annotations

import ast
from typing import Optional

from .core import FileLint, Rule, rule


def _scoped(rel: str, files: tuple = (), prefixes: tuple = ()) -> bool:
    return rel in files or any(rel.startswith(p) for p in prefixes)


# --------------------------------------------------------------------------
@rule
class SimClockPurity(Rule):
    name = "sim-clock-purity"
    summary = "sim-scope code must use the sim clock, not wall clocks"
    explain = """\
The simulator's determinism (and every cost/attainment number derived
from it) requires that simulated time comes only from the event loop's
sim clock.  In sim scope — core/simulator.py, orchestrator/, traces/ —
ALL wall-clock reads are banned: time.time/monotonic/perf_counter(_ns),
datetime.now/utcnow/today.  Real-infrastructure latency measurement in
sim-scope modules must go through obs.trace.wall_now(), the sanctioned
dual-clock helper (PR 6's design: sim time for semantics, wall time for
observability only).

Outside sim scope, only NON-MONOTONIC clocks (time.time, datetime.now)
are flagged: interval math on them breaks under NTP steps — use
time.perf_counter().  Epoch timestamps that genuinely must be wall time
(e.g. the real serving engine's request arrival stamps) carry a
`# lint: allow[sim-clock-purity]` pragma with a justifying comment.

repro/obs/ is exempt: it is the sanctioned wall-clock layer (span
tracing, metric export timestamps)."""
    node_types = (ast.Call,)

    SIM_FILES = ("repro/core/simulator.py",)
    SIM_PREFIXES = ("repro/orchestrator/", "repro/traces/")
    WALL = {
        "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
        "time.perf_counter", "time.perf_counter_ns",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    }
    NON_MONOTONIC = {
        "time.time", "time.time_ns",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    }

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("repro/") and not rel.startswith("repro/obs/")

    def visit(self, node: ast.Call, ctx: FileLint) -> None:
        q = ctx.qualname(node.func)
        if q is None:
            return
        in_sim = _scoped(ctx.rel, self.SIM_FILES, self.SIM_PREFIXES)
        if in_sim and q in self.WALL:
            ctx.report(self, node,
                       f"wall clock {q}() in sim scope; use the sim clock, "
                       "or obs.trace.wall_now() for latency measurement")
        elif not in_sim and q in self.NON_MONOTONIC:
            ctx.report(self, node,
                       f"non-monotonic clock {q}(); use time.perf_counter() "
                       "for intervals (pragma epoch timestamps that must be "
                       "wall time)")


# --------------------------------------------------------------------------
@rule
class SeededRng(Rule):
    name = "seeded-rng"
    summary = "no global-state RNG; require explicit seeded generators"
    explain = """\
Reproducibility contract: every random draw flows from an explicit
seeded generator — random.Random(seed), numpy.random.default_rng(seed),
or a jax PRNG key — threaded through the call chain.  Module-level
random.* functions and the legacy numpy.random.<fn> aliases mutate
hidden global state, so two call sites can perturb each other and
"same seed, same trace" silently stops holding.  Flagged: any call
resolving to random.<fn> (except the generator constructors
Random/SystemRandom) or numpy.random.<fn> (except default_rng and the
Generator/BitGenerator constructors).  jax.random is inherently
key-passing and never flagged.  Applies to tests/ and benchmarks/ too:
an unseeded draw in a test is a flake, in a benchmark an
unreproducible number."""
    node_types = (ast.Call,)

    PY_OK = {"Random", "SystemRandom"}
    NP_OK = {"default_rng", "Generator", "RandomState", "SeedSequence",
             "BitGenerator", "PCG64", "PCG64DXSM", "Philox", "MT19937",
             "SFC64"}

    def applies_to(self, rel: str) -> bool:
        return rel.startswith(("repro/", "tests/", "benchmarks/"))

    def visit(self, node: ast.Call, ctx: FileLint) -> None:
        q = ctx.qualname(node.func)
        if not q:
            return
        parts = q.split(".")
        if parts[0] == "random" and len(parts) == 2 \
                and parts[1] not in self.PY_OK:
            ctx.report(self, node,
                       f"global-state RNG {q}(); thread a seeded "
                       "random.Random(seed) instead")
        elif parts[:2] == ["numpy", "random"] and len(parts) == 3 \
                and parts[2] not in self.NP_OK:
            ctx.report(self, node,
                       f"global-state RNG {q}(); use "
                       "numpy.random.default_rng(seed)")


# --------------------------------------------------------------------------
@rule
class BucketEdges(Rule):
    name = "bucket-edges"
    summary = "half-open bucket-edge math lives only in core/workload.py"
    explain = """\
PR 3 unified request bucketing on ONE half-open convention
(edges[k] <= x < edges[k+1], searchsorted side="right"), after
edge-drift bugs where two call sites disagreed about which bucket a
boundary request lands in — which flips which GPU looks cheapest for
that bucket.  All bucketization goes through workload.edge_bucket /
Workload.bucket_indices.  Outside core/workload.py, any
searchsorted/digitize/bisect call is flagged: if it is genuinely not
bucket-edge math (e.g. the solver's sorted-cost cutoff, event-index
lookup in a sorted arrival array), pragma it with a comment saying what
it searches."""
    node_types = (ast.Call,)

    BISECT = {"bisect.bisect", "bisect.bisect_left", "bisect.bisect_right",
              "bisect.insort", "bisect.insort_left", "bisect.insort_right"}

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("repro/") and rel != "repro/core/workload.py"

    def visit(self, node: ast.Call, ctx: FileLint) -> None:
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("searchsorted", "digitize"):
            ctx.report(self, node,
                       f".{node.func.attr}() outside core/workload.py; "
                       "bucketization must use workload.edge_bucket / "
                       "bucket_indices (pragma if not bucket-edge math)")
            return
        q = ctx.qualname(node.func)
        if q in self.BISECT:
            ctx.report(self, node,
                       f"{q}() outside core/workload.py; bucketization must "
                       "use workload.edge_bucket / bucket_indices (pragma "
                       "if not bucket-edge math)")


# --------------------------------------------------------------------------
@rule
class InfMaskConvention(Rule):
    name = "inf-mask-convention"
    summary = "infeasibility is math.inf masks, never 1e9-style sentinels"
    explain = """\
The load matrix encodes "this slice cannot run on this column" as
math.inf, and every solver layer tests np.isfinite.  A big-M sentinel
(1e9 and friends) is poison here: it survives arithmetic, so a
"forbidden" column can still win a cost comparison after enough
multiplication, silently flipping which GPU mix is cheapest — the exact
inconsistency class arxiv 2502.00722 shows flips heterogeneous
cost rankings.  In the mask-carrying modules (core/ilp.py,
core/loadmatrix.py, core/allocator.py, core/crosscheck.py,
regions/problem.py) any numeric literal with magnitude >= 1e8 is
flagged; use float("inf") / math.inf / np.inf."""
    node_types = (ast.Constant,)

    FILES = ("repro/core/ilp.py", "repro/core/loadmatrix.py",
             "repro/core/allocator.py", "repro/core/crosscheck.py",
             "repro/regions/problem.py")

    def applies_to(self, rel: str) -> bool:
        return rel in self.FILES

    def visit(self, node: ast.Constant, ctx: FileLint) -> None:
        v = node.value
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and abs(v) >= 1e8:
            ctx.report(self, node,
                       f"sentinel-sized literal {v!r} in a mask-carrying "
                       "module; infeasibility must be math.inf")


# --------------------------------------------------------------------------
@rule
class PoolKeyLiterals(Rule):
    name = "pool-key-literals"
    summary = "pool names are composed/parsed only by accelerators.py helpers"
    explain = """\
Pool names compose as name[xN][:spot]@region and PR 5's composition-
order bug (building "g:spot@r" one place and "g@r:spot" another) made
two layers disagree about which pool a column belonged to.  All
composition and parsing goes through core/accelerators.py
(market_pool, with_region, pool_key, split_region, is_spot_pool).
Flagged outside that file (including tests/ and benchmarks/): f-string
fragments containing ":spot"; endswith/startswith(":spot"); and — in
core/, regions/, orchestrator/, serving/ — the "{x}@{y}" f-string
composition shape and split/partition("@") parsing.  Display-only
strings that merely look similar carry a pragma saying they never name
a pool."""
    node_types = (ast.Call, ast.JoinedStr)

    AT_PREFIXES = ("repro/core/", "repro/regions/", "repro/orchestrator/",
                   "repro/serving/")
    SPLITTERS = ("split", "rsplit", "partition", "rpartition")

    def applies_to(self, rel: str) -> bool:
        return rel.startswith(("repro/", "tests/", "benchmarks/")) \
            and rel != "repro/core/accelerators.py"

    def visit(self, node: ast.AST, ctx: FileLint) -> None:
        if isinstance(node, ast.JoinedStr):
            self._joined(node, ctx)
        elif isinstance(node, ast.Call):
            self._call(node, ctx)

    def _joined(self, node: ast.JoinedStr, ctx: FileLint) -> None:
        in_at = _scoped(ctx.rel, prefixes=self.AT_PREFIXES)
        vals = node.values
        for i, part in enumerate(vals):
            if not (isinstance(part, ast.Constant)
                    and isinstance(part.value, str)):
                continue
            if ":spot" in part.value:
                ctx.report(self, node,
                           'hand-built ":spot" pool suffix in f-string; use '
                           "accelerators.market_pool/pool_key")
            elif in_at and part.value == "@" and 0 < i < len(vals) - 1 \
                    and isinstance(vals[i - 1], ast.FormattedValue) \
                    and isinstance(vals[i + 1], ast.FormattedValue):
                ctx.report(self, node,
                           'hand-built "{x}@{y}" composition; use '
                           "accelerators.with_region/pool_key (pragma "
                           "display-only strings)")

    def _call(self, node: ast.Call, ctx: FileLint) -> None:
        if not isinstance(node.func, ast.Attribute):
            return
        attr = node.func.attr
        args = node.args
        first = args[0].value if args and isinstance(args[0], ast.Constant) \
            else None
        if attr in ("endswith", "startswith") and isinstance(first, str) \
                and ":spot" in first:
            ctx.report(self, node,
                       f'.{attr}(":spot") re-parses pool names; use '
                       "accelerators.is_spot_pool")
        elif attr in self.SPLITTERS and first == "@" \
                and _scoped(ctx.rel, prefixes=self.AT_PREFIXES):
            ctx.report(self, node,
                       f'.{attr}("@") re-parses pool names; use '
                       "accelerators.split_region")


# --------------------------------------------------------------------------
@rule
class FloatEq(Rule):
    name = "float-eq"
    summary = "no ==/!= against float-typed expressions in solver modules"
    explain = """\
The solver stack compares costs that went through ceil/sum/matmul chains;
exact equality on such floats is representation-dependent, and a parity
assertion that holds on one machine can fail on another (or after a
numpy upgrade).  In solver modules (core/ilp.py, loadmatrix.py,
allocator.py, crosscheck.py, autoscaler.py, regions/, and all of
benchmarks/), ==/!= where either operand is float-typed on its face — a
float literal, float(...), math.inf/np.inf/nan — is flagged.  Use
math.isclose/np.isclose or the module's _EPS tolerances.  Integer-valued
comparisons (indices, counts) are untouched.  Config-validation equality
on user-entered floats — and golden-regression assertions in tests,
which are *intentionally* byte-exact — may be pragma'd with a
comment."""
    node_types = (ast.Compare,)

    FILES = ("repro/core/ilp.py", "repro/core/loadmatrix.py",
             "repro/core/allocator.py", "repro/core/crosscheck.py",
             "repro/core/autoscaler.py")
    PREFIXES = ("repro/regions/", "benchmarks/", "tests/")
    FLOAT_ATTRS = {"math.inf", "math.nan", "numpy.inf", "numpy.nan",
                   "math.pi", "math.e"}

    def applies_to(self, rel: str) -> bool:
        return _scoped(rel, self.FILES, self.PREFIXES)

    def _floaty(self, node: ast.AST, ctx: FileLint) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, float)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return self._floaty(node.operand, ctx)
        if isinstance(node, ast.Call):
            return ctx.qualname(node.func) == "float"
        if isinstance(node, ast.Attribute):
            return ctx.qualname(node) in self.FLOAT_ATTRS
        return False

    def visit(self, node: ast.Compare, ctx: FileLint) -> None:
        operands = [node.left] + list(node.comparators)
        for i, op in enumerate(node.ops):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            if self._floaty(operands[i], ctx) \
                    or self._floaty(operands[i + 1], ctx):
                ctx.report(self, node,
                           "exact ==/!= on a float-typed expression in "
                           "solver code; use math.isclose/np.isclose or an "
                           "_EPS tolerance")
                return


# --------------------------------------------------------------------------
@rule
class ObsLabelDiscipline(Rule):
    name = "obs-label-discipline"
    summary = "metric labelnames are literal tuples; no unbounded-id labels"
    explain = """\
The metrics registry keys each (family, label-values) child in a dict
that lives for the process: label names must be knowable statically
(literal tuple/list of strings at the counter/gauge/histogram call) and
label VALUES must be low-cardinality.  A request id / instance id /
timestamp label grows one child per request and the registry becomes an
unbounded memory leak that also blows up every export.  Flagged:
non-literal labelnames arguments, and labelnames or .labels() kwargs
drawn from the known-unbounded set (request_id, rid, inst_id,
instance_id, timestamp, ts, uuid, trace_id, span_id).  obs/metrics.py
itself (the registry implementation) is exempt."""
    node_types = (ast.Call,)

    FAMILIES = ("counter", "gauge", "histogram")
    DENY = {"request_id", "rid", "req_id", "inst_id", "instance_id",
            "timestamp", "ts", "uuid", "trace_id", "span_id"}

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("repro/") and rel != "repro/obs/metrics.py"

    def visit(self, node: ast.Call, ctx: FileLint) -> None:
        if not isinstance(node.func, ast.Attribute):
            return
        attr = node.func.attr
        if attr in self.FAMILIES:
            self._family(node, ctx)
        elif attr == "labels":
            for kw in node.keywords:
                if kw.arg in self.DENY:
                    ctx.report(self, node,
                               f"unbounded-cardinality label {kw.arg!r} in "
                               ".labels(); one child per id leaks the "
                               "registry")

    def _family(self, node: ast.Call, ctx: FileLint) -> None:
        labelnames: Optional[ast.AST] = None
        for kw in node.keywords:
            if kw.arg == "labelnames":
                labelnames = kw.value
        if labelnames is None and len(node.args) >= 3:
            labelnames = node.args[2]
        if labelnames is None:
            return
        if not (isinstance(labelnames, (ast.Tuple, ast.List))
                and all(isinstance(e, ast.Constant)
                        and isinstance(e.value, str)
                        for e in labelnames.elts)):
            ctx.report(self, node,
                       "metric labelnames must be a literal tuple/list of "
                       "string constants (cardinality must be auditable "
                       "statically)")
            return
        for e in labelnames.elts:
            if e.value in self.DENY:
                ctx.report(self, node,
                           f"unbounded-cardinality label {e.value!r} in "
                           "metric labelnames")


# --------------------------------------------------------------------------
@rule
class JitPurity(Rule):
    name = "jit-purity"
    summary = "jit/pallas kernel bodies stay pure: no prints, syncs, clocks"
    explain = """\
Bodies traced by jax.jit or run as pallas_call kernels execute at trace
time and then never again: a print() fires once (or not at all inside
pallas), .item()/.tolist()/.block_until_ready() force a host sync that
serializes the pipeline, wall-clock and global-RNG reads bake one
trace-time value into the compiled artifact, and global/nonlocal
mutation of closed-over Python state is invisible to retraces.  In
kernels/, functions decorated with jax.jit (directly or via
functools.partial) or referenced as a pallas_call kernel (directly or
via functools.partial) are checked for all of the above.  Debug paths
should use jax.debug.print / jax.debug.callback, which are
trace-aware."""
    node_types = (ast.Call, ast.FunctionDef, ast.AsyncFunctionDef,
                  ast.Global, ast.Nonlocal)

    SYNC_ATTRS = {"item", "tolist", "block_until_ready"}

    def __init__(self) -> None:
        self._defs: dict[str, ast.AST] = {}
        self._jit_ids: set[int] = set()
        self._kernel_names: set[str] = set()
        self._candidates: list[tuple[frozenset, ast.AST, str]] = []

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("repro/kernels/")

    # -- collection --------------------------------------------------------
    def _dec_is_jit(self, dec: ast.AST, ctx: FileLint) -> bool:
        for sub in ast.walk(dec):
            q = ctx.qualname(sub) if isinstance(
                sub, (ast.Name, ast.Attribute)) else None
            if q and (q in ("jax.jit", "jax.pmap", "jit")
                      or q.endswith(".pallas_call")):
                return True
        return False

    def _flag(self, node: ast.AST, ctx: FileLint, msg: str) -> None:
        if ctx.func_stack:
            self._candidates.append(
                (frozenset(id(f) for f in ctx.func_stack), node, msg))

    def visit(self, node: ast.AST, ctx: FileLint) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._defs[node.name] = node
            if any(self._dec_is_jit(d, ctx) for d in node.decorator_list):
                self._jit_ids.add(id(node))
            return
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            self._flag(node, ctx,
                       f"{type(node).__name__.lower()} mutation of "
                       "closed-over Python state in a traced body is "
                       "invisible to retraces")
            return
        # Call
        q = ctx.qualname(node.func)
        if q and q.endswith(".pallas_call") and node.args:
            self._kernel(node.args[0], ctx)
        if q == "print":
            self._flag(node, ctx,
                       "print() in a traced body fires at trace time only; "
                       "use jax.debug.print")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in self.SYNC_ATTRS and not node.args:
            self._flag(node, ctx,
                       f".{node.func.attr}() forces a host sync inside a "
                       "traced body")
        elif q and (q.startswith("time.") or q.startswith("datetime.")):
            self._flag(node, ctx,
                       f"{q}() bakes a trace-time clock value into the "
                       "compiled artifact")
        elif q and (q.split(".")[0] == "random"
                    or q.startswith("numpy.random.")):
            self._flag(node, ctx,
                       f"{q}() draws host RNG at trace time; use a jax "
                       "PRNG key argument")

    def _kernel(self, arg: ast.AST, ctx: FileLint) -> None:
        # pallas_call(_kernel, ...) or pallas_call(partial(_kernel, ...), ...)
        if isinstance(arg, ast.Call) \
                and ctx.qualname(arg.func) in ("functools.partial", "partial") \
                and arg.args:
            arg = arg.args[0]
        if isinstance(arg, ast.Name):
            self._kernel_names.add(arg.id)

    # -- resolution --------------------------------------------------------
    def finish(self, ctx: FileLint) -> None:
        jit_ids = set(self._jit_ids)
        jit_ids.update(id(self._defs[n]) for n in self._kernel_names
                       if n in self._defs)
        for stack_ids, node, msg in self._candidates:
            if stack_ids & jit_ids:
                ctx.report(self, node, msg)


# --------------------------------------------------------------------------
@rule
class SolverLayerParity(Rule):
    name = "solver-layer-parity"
    summary = "every ILPProblem constraint field reaches all four solver layers"
    explain = """\
The repo's cost claims rest on four solver layers — greedy warm start
(_greedy), local search (_local_search), branch-and-bound (solve), and
the brute-force reference (solve_brute_force) — enforcing EXACTLY the
same constraint set.  Historically every new axis (TP chip pools, model
rows, spot floors, regions) had to be hand-wired into each layer, and a
layer that silently skips a cap makes cross-checks pass on small
instances while production allocations violate availability.

This rule parses core/ilp.py structurally: the constraint fields are
ILPProblem's dataclass fields minus the data fields
(loads/costs/gpu_names/bucket_of_slice) minus any field whose preceding
comment block contains the word "metadata" (the sanctioned way to add a
non-constraint field, e.g. spot_col/region_col — say WHY it is
metadata).  For each layer it computes the set of fields reachable from
the layer function through module helpers and ILPProblem
methods/properties (counts_within_caps, group_matrix, grouped_caps, ...)
WITHOUT passing through the other three layers — each layer must
enforce caps via its own call chain, not by delegating to another
layer.  Any constraint field missing from any layer's closure is a
violation: new cap axes can never silently skip a layer."""
    # everything happens in finish(); no per-node dispatch
    node_types = ()

    DATA_FIELDS = {"loads", "costs", "gpu_names", "bucket_of_slice"}
    LAYERS = {
        "greedy warm start": "_greedy",
        "local search": "_local_search",
        "branch-and-bound": "solve",
        "brute-force reference": "solve_brute_force",
    }

    def applies_to(self, rel: str) -> bool:
        return rel == "repro/core/ilp.py"

    @staticmethod
    def _names_and_attrs(fn: ast.AST) -> tuple[set, set]:
        """All Name ids and Attribute attrs in a function's subtree."""
        names, attrs = set(), set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
            elif isinstance(sub, ast.Attribute):
                attrs.add(sub.attr)
        return names, attrs

    def _metadata_fields(self, cls: ast.ClassDef, ctx: FileLint) -> set:
        """Fields whose directly-preceding comment block says 'metadata'."""
        out = set()
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            ln = stmt.lineno - 1
            while ln >= 1 and ctx.line_text(ln).strip().startswith("#"):
                if "metadata" in ctx.line_text(ln):
                    out.add(stmt.target.id)
                    break
                ln -= 1
        return out

    def finish(self, ctx: FileLint) -> None:
        cls = next((n for n in ctx.tree.body
                    if isinstance(n, ast.ClassDef)
                    and n.name == "ILPProblem"), None)
        if cls is None:
            ctx.report(self, ctx.tree,
                       "ILPProblem class not found in core/ilp.py")
            return
        fields = [s.target.id for s in cls.body
                  if isinstance(s, ast.AnnAssign)
                  and isinstance(s.target, ast.Name)]
        constraint = (set(fields) - self.DATA_FIELDS
                      - self._metadata_fields(cls, ctx))
        # ILPProblem methods/properties: name -> (fields touched, members used)
        members: dict[str, tuple[set, set]] = {}
        member_names = {s.name for s in cls.body
                        if isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}
        for s in cls.body:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _, attrs = self._names_and_attrs(s)
                members[s.name] = (attrs & constraint, attrs & member_names)
        # module-level functions: name -> (node, names used, attrs used)
        funcs = {n.name: n for n in ctx.tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        layer_fns = set(self.LAYERS.values())
        for layer, fn_name in self.LAYERS.items():
            if fn_name not in funcs:
                ctx.report(self, ctx.tree,
                           f"solver layer {layer!r} ({fn_name}) not found "
                           "in core/ilp.py")
                continue
            covered: set = set()
            seen: set = set()
            frontier = [fn_name]
            while frontier:
                cur = frontier.pop()
                if cur in seen:
                    continue
                seen.add(cur)
                names, attrs = self._names_and_attrs(funcs[cur])
                covered |= attrs & constraint
                # ILPProblem methods/properties reached via attribute access
                mseen: set = set()
                mfrontier = list(attrs & member_names)
                while mfrontier:
                    m = mfrontier.pop()
                    if m in mseen:
                        continue
                    mseen.add(m)
                    mfields, mmembers = members[m]
                    covered |= mfields
                    mfrontier.extend(mmembers - mseen)
                # other module functions, never through another layer
                for callee in names & set(funcs):
                    if callee != fn_name and callee in layer_fns:
                        continue
                    frontier.append(callee)
            for missing in sorted(constraint - covered):
                ctx.report(
                    self, funcs[fn_name],
                    f"ILPProblem constraint field {missing!r} is never "
                    f"referenced by solver layer {layer!r} ({fn_name}): "
                    "every cap axis must be enforced by all four layers "
                    "(mark non-constraint fields with a '# metadata' "
                    "comment)")


# --------------------------------------------------------------------------
@rule
class UnitsChecker(Rule):
    name = "units"
    summary = "dimensional analysis of the cost/throughput arithmetic"
    explain = """\
Every headline number — $/h savings, tokens/$, SLO attainment — is the
output of hand-written unit arithmetic, and a silent unit mix-up
($/h added to $/s, a GB where bytes were meant, RTT-seconds compared to
an hours budget) corrupts the result without failing any test.  This
rule runs repro.analysis.dataflow: an abstract interpreter that
propagates units-of-measure through assignments, checks +/-/comparisons
/min/max/isclose for dimensional compatibility, and composes units
algebraically through * and / (so r * (i + o) * 3600.0 / acc.price_hr
checks out as tok/$).

Units are seeded from naming conventions (*_s -> seconds, *_hr -> hours,
price_hr -> $/h, *_gbs -> GB/s, *_bytes -> B, tput/rate -> req/s,
X_per_Y -> unit(X)/unit(Y), ...), from the dataflow.ANNOTATIONS
registry for names that defy their suffix (preemption_rate is 1/h), and
from `# unit: <expr>` comments — on an assignment they declare (and
check) the target's unit; on a dataclass field line they type the
field; on a def's own line they declare the return unit; on a
continuation line of a multi-line signature they type that parameter.
Count-like units (req, step, seq, chip) are dimensionless: the repo
freely mixes per-request and absolute quantities, so req/s is tracked
as 1/s while $/h vs $/s and tok vs $ stay distinct.  Conversion
literals (3600 = s/h, 1e9 = B/GB, 1e12 = flop/Tflop) apply only when
they cancel against the other operand.

Parameter and return units flow interprocedurally across the solver/
serving modules (dataflow.PROJECT_MODULES), so a function returning
seconds cannot be added to hours at a call site in another file.  Fix a
finding by correcting the math, annotating the name with `# unit:` (or
the registry) when the convention mis-reads it, or pragma'ing with
justification."""
    node_types = ()

    FILES = ("repro/core/engine_model.py", "repro/core/loadmatrix.py",
             "repro/core/simulator.py", "repro/serving/kv_cache.py")
    PREFIXES = ("repro/regions/", "repro/orchestrator/")

    def applies_to(self, rel: str) -> bool:
        return _scoped(rel, self.FILES, self.PREFIXES)

    def finish(self, ctx: FileLint) -> None:
        from . import dataflow
        try:
            external = dataflow.project_summaries(exclude_rel=ctx.rel)
        except Exception:            # project files unreadable: intra only
            external = {}
        mod = dataflow.ModuleUnits(ctx.source, ctx.rel,
                                   external=external, tree=ctx.tree)
        for node, msg in mod.violations:
            ctx.report(self, node, msg)


# --------------------------------------------------------------------------
@rule
class ParamMutation(Rule):
    name = "param-mutation"
    summary = "no in-place mutation of ndarrays reachable from parameters"
    explain = """\
PR 8's vectorized solver shipped a real bug in exactly this class: a
hot loop mutated an ndarray the caller still owned, so a "pure"
re-solve corrupted its input and downstream allocations went silently
wrong.  In the solver modules (core/ilp.py, loadmatrix.py,
allocator.py, autoscaler.py, dominance.py, crosscheck.py, regions/),
this rule runs an aliasing dataflow analysis (repro.analysis.dataflow.
param_mutations): starting from the function's parameters it tracks
aliases through assignments, views (.reshape/.ravel/np.asarray/...) and
conditional expressions — copies (.copy()/np.array/.astype) break the
alias — and flags in-place mutation of anything still parameter-
reachable: subscript stores (x[...] = v), augmented subscript assigns,
augmented assigns on ndarray-annotated parameters (+= is __iadd__, in
place), mutator methods (.sort()/.fill()/.put()/...), out= kwargs, and
mutator functions (np.copyto/np.put/np.fill_diagonal/...).

Functions whose *contract* is in-place mutation (the arrays passed in
ARE the arrays returned — e.g. _local_search) are listed in
dataflow.SANCTIONED_MUTATORS; everything else copies first or carries
a pragma with a justifying comment."""
    node_types = ()

    FILES = ("repro/core/ilp.py", "repro/core/loadmatrix.py",
             "repro/core/allocator.py", "repro/core/autoscaler.py",
             "repro/core/dominance.py", "repro/core/crosscheck.py")
    PREFIXES = ("repro/regions/",)

    def applies_to(self, rel: str) -> bool:
        return _scoped(rel, self.FILES, self.PREFIXES)

    def finish(self, ctx: FileLint) -> None:
        from . import dataflow
        imports = dataflow._Imports(ctx.tree)
        funcs: list[tuple[ast.AST, str]] = []
        for n in ctx.tree.body:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs.append((n, n.name))
            elif isinstance(n, ast.ClassDef):
                for m in n.body:
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        funcs.append((m, f"{n.name}.{m.name}"))
        for fn, qual in funcs:
            for mut in dataflow.param_mutations(fn, imports, ctx.rel,
                                                qualname=qual):
                ctx.report(self, mut.node,
                           f"in-place mutation of caller-owned "
                           f"parameter {mut.param!r}: {mut.what} "
                           "(copy first, add the function to "
                           "dataflow.SANCTIONED_MUTATORS if mutation "
                           "is its contract, or pragma with "
                           "justification)")


# --------------------------------------------------------------------------
class _LineAnchor:
    """Violation anchor for findings tied to a line, not an AST node."""

    def __init__(self, lineno: int):
        self.lineno = lineno
        self.col_offset = 0


@rule
class DeadPragma(Rule):
    name = "dead-pragma"
    summary = "lint pragmas must suppress something; baselines must match"
    explain = """\
Escape hatches rot: a `# lint: allow[rule]` pragma whose violation was
since fixed (or whose rule was renamed) silently disables future
checking on that line, and a baseline fingerprint whose offending line
was edited no longer grandfathers anything but still bloats the file.
After all other rules run, this rule reports every pragma tag that
suppressed nothing — including tags naming unknown rules — and the CLI
reports baseline entries that matched no violation (judged only when
the entry's rule was part of the run; `allow[*]` deadness is judged
only on full-rule-set runs, and its report bypasses the pragma so it
cannot self-suppress).  Use --prune-baseline to rewrite the baseline
minus stale entries.  tests/ is exempt: lint fixtures there embed
pragma strings that are test *data*, not escape hatches."""
    node_types = ()
    runs_last = True

    def applies_to(self, rel: str) -> bool:
        return rel.startswith(("repro/", "benchmarks/"))

    def finish(self, ctx: FileLint) -> None:
        from .core import RULES
        for lineno in sorted(ctx._pragmas):
            tags = ctx._pragmas[lineno]
            hits = ctx.pragma_hits.get(lineno, set())
            for tag in sorted(tags):
                if tag in hits:
                    continue
                anchor = _LineAnchor(lineno)
                if tag == "*":
                    # judged only when every rule ran; bypasses pragma
                    # suppression (allow[*] would self-suppress)
                    if ctx.selected is None and "*" not in hits:
                        ctx.report(self, anchor,
                                   "allow[*] suppresses nothing on this "
                                   "line; remove it", force=True)
                    continue
                if tag not in RULES:
                    ctx.report(self, anchor,
                               f"pragma names unknown rule {tag!r}; "
                               "remove or fix the tag")
                    continue
                if ctx.selected is not None and tag not in ctx.selected:
                    continue     # rule not in this run: can't judge
                ctx.report(self, anchor,
                           f"pragma allow[{tag}] suppresses nothing on "
                           "this line; the violation was fixed or moved "
                           "— remove the pragma")
