"""Optimizers: AdamW and Adafactor (factored second moment).

Pure-function API (no optax dependency):
    state  = init(params, kind)                  # eval_shape-safe
    axes   = state_axes(params_like, param_axes, kind)
    params, state = update(params, grads, state, kind, lr, ...)

Adafactor (beta1=0, factored v) is used for the ≥398B configs so optimizer
state fits v5e HBM at 512 chips; AdamW elsewhere.  AdamW moments remap
"model_d" -> data axes at sharding time (ZeRO-1-style optimizer-state
sharding) — see launch/steps.py:opt_rules.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any

_IS_AXES_LEAF = lambda v: isinstance(v, tuple) and all(
    isinstance(e, (str, type(None))) for e in v)


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


def init(params: Tree, kind: str) -> Tree:
    if kind == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }
    if kind == "adafactor":
        fac = {}
        for path, p in jax.tree_util.tree_leaves_with_path(params):
            if len(p.shape) >= 2:
                fac[_leaf_key(path)] = {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            else:
                fac[_leaf_key(path)] = {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": fac, "count": jnp.zeros((), jnp.int32)}
    raise ValueError(kind)


def state_axes(params_like: Tree, param_axes: Tree, kind: str) -> Tree:
    """Logical-axes tree matching init()'s structure. ``params_like`` may be
    ShapeDtypeStructs (only .shape is used)."""
    if kind == "adamw":
        return {"m": param_axes, "v": param_axes, "count": ()}
    if kind == "adafactor":
        fac = {}
        leaves_p = jax.tree_util.tree_leaves_with_path(params_like)
        leaves_a = [a for _, a in jax.tree_util.tree_leaves_with_path(
            param_axes, is_leaf=_IS_AXES_LEAF)]
        for (path, p), a in zip(leaves_p, leaves_a):
            if len(p.shape) >= 2:
                fac[_leaf_key(path)] = {
                    "vr": tuple(a[:-1]),
                    "vc": tuple(a[:-2]) + (a[-1],),
                }
            else:
                fac[_leaf_key(path)] = {"v": tuple(a)}
        return {"fac": fac, "count": ()}
    raise ValueError(kind)


def _adamw_update(p, g, m, v, lr, b1, b2, eps, wd, count):
    gf = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    c = count.astype(jnp.float32)
    mhat = m_new / (1 - b1 ** c)
    vhat = v_new / (1 - b2 ** c)
    upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
    return p_new, m_new, v_new


def _adafactor_update(p, g, st, lr, decay, count):
    gf = g.astype(jnp.float32)
    g2 = gf * gf + 1e-30
    out_st = {}
    if p.ndim >= 2:
        vr = decay * st["vr"] + (1 - decay) * g2.mean(axis=-1)
        vc = decay * st["vc"] + (1 - decay) * g2.mean(axis=-2)
        out_st["vr"], out_st["vc"] = vr, vc
        denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30))[
            ..., None] * vc[..., None, :]
        upd = gf * jax.lax.rsqrt(jnp.maximum(denom, 1e-30))
    else:
        v = decay * st["v"] + (1 - decay) * g2
        out_st["v"] = v
        upd = gf * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
    # update clipping (RMS <= 1)
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
    return p_new, out_st


def update(params: Tree, grads: Tree, state: Tree, kind: str, lr,
           *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.0, fac_decay: float = 0.99):
    count = state["count"] + 1
    if kind == "adamw":
        out = jax.tree.map(
            lambda p, g, m, v: _adamw_update(
                p, g, m, v, lr, b1, b2, eps, weight_decay, count),
            params, grads, state["m"], state["v"])
        is_triple = lambda t: isinstance(t, tuple) and len(t) == 3
        p_new = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
        m_new = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
        v_new = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
        return p_new, {"m": m_new, "v": v_new, "count": count}
    if kind == "adafactor":
        leaves_p = jax.tree_util.tree_leaves_with_path(params)
        grads_flat = jax.tree_util.tree_leaves(grads)
        new_p_flat, new_fac = [], {}
        for (path, p), g in zip(leaves_p, grads_flat):
            key = _leaf_key(path)
            p_new, st_new = _adafactor_update(
                p, g, state["fac"][key], lr, fac_decay, count)
            new_p_flat.append(p_new)
            new_fac[key] = st_new
        treedef = jax.tree_util.tree_structure(params)
        return (jax.tree_util.tree_unflatten(treedef, new_p_flat),
                {"fac": new_fac, "count": count})
    raise ValueError(kind)


def lr_schedule(step, *, peak: float = 3e-4, warmup: int = 100,
                total: int = 10_000, floor: float = 3e-5):
    stepf = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, stepf / warmup)
    frac = jnp.clip((stepf - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(stepf < warmup, warm, cos)
