"""Deterministic synthetic token pipeline.

Produces an infinite stream of (tokens, labels) batches with a
Zipf-distributed vocabulary and injected n-gram structure (so small models
have something learnable and loss visibly decreases in the examples).
Sharded host feed: each data-parallel host slice draws a disjoint
deterministic key stream — resumable from (seed, step) alone, which is what
checkpoint/restart needs (no pipeline state to snapshot).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_codebooks: int = 0
    structure: bool = True     # inject learnable bigram structure


class SyntheticDataset:
    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        # fixed learnable bigram successor table
        rng = np.random.default_rng(cfg.seed ^ 0xBEEF)
        self._succ = rng.integers(0, cfg.vocab_size,
                                  size=cfg.vocab_size).astype(np.int32)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 31 + self.host_id)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        shape = (self.local_batch, cfg.seq_len + 1)
        if cfg.n_codebooks:
            shape = shape + (cfg.n_codebooks,)
        x = rng.zipf(cfg.zipf_a, size=shape).astype(np.int64)
        x = np.clip(x - 1, 0, cfg.vocab_size - 1).astype(np.int32)
        if cfg.structure and not cfg.n_codebooks:
            # half of the positions follow the deterministic bigram table
            follow = rng.random((self.local_batch, cfg.seq_len)) < 0.5
            for t in range(1, cfg.seq_len + 1):
                x[:, t] = np.where(follow[:, t - 1],
                                   self._succ[x[:, t - 1]], x[:, t])
        return {"tokens": x[:, :-1].copy(), "labels": x[:, 1:].copy()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
