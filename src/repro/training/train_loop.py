"""Training loop with checkpoint/restart fault tolerance.

Wraps launch/steps.build_train_step with: data pipeline, periodic
checkpointing (async, atomic), automatic resume from the latest committed
step, and a failure-injection hook used by the fault-tolerance test and the
elastic example.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.launch.steps import build_train_step
from repro.models import transformer as T
from repro.training import optimizer as OPT
from repro.training.data import DataConfig, SyntheticDataset


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    n_micro: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0


def train(cfg: ModelConfig, tcfg: TrainConfig,
          *, fail_at_step: Optional[int] = None,
          log_fn: Callable[[str], None] = print) -> dict:
    """Returns {"losses": [...], "resumed_from": int|None, "steps_run": int}."""
    data = SyntheticDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
        global_batch=tcfg.global_batch, seed=tcfg.seed,
        n_codebooks=cfg.n_codebooks))
    step_fn = jax.jit(build_train_step(cfg, n_micro=tcfg.n_micro),
                      donate_argnums=(0, 1))

    params = T.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    opt_state = OPT.init(params, cfg.optimizer)

    ckpt = Checkpointer(tcfg.ckpt_dir, async_save=tcfg.ckpt_async) \
        if tcfg.ckpt_dir else None
    start = 0
    resumed_from = None
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            resumed_from = latest
            log_fn(f"[train] resumed from step {latest}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, tcfg.steps):
        if fail_at_step is not None and step == fail_at_step:
            if ckpt:
                ckpt.wait()
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.n_vision_tokens:
            batch["vision_embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(tcfg.seed), step),
                (tcfg.global_batch, cfg.n_vision_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % tcfg.log_every == 0:
            dt = (time.perf_counter() - t0) / max(1, len(losses))
            log_fn(f"[train] step {step+1}/{tcfg.steps} "
                   f"loss={loss:.4f} ({dt*1e3:.0f} ms/step)")
        if ckpt is not None and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt is not None:
        ckpt.save(tcfg.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    return {"losses": losses, "resumed_from": resumed_from,
            "steps_run": len(losses), "params": params}
