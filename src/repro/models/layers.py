"""Core transformer layers: norms, RoPE, GQA attention (global / sliding-
window / cross), dense MLP variants.  All layers are pure functions over a
param dict; init_* functions return (params, logical_axes) pytrees with
matching structure so the launcher can derive shardings mechanically.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops

Params = dict
Axes = dict


def _norm_init(d: int):
    return jnp.ones((d,), jnp.float32), ("model_d",)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, Dh); positions: (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, spec: LayerSpec, key) -> tuple[Params, Axes]:
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    s_q = (2.0 / (D + H * Dh)) ** 0.5
    p: Params = {
        "wq": (jax.random.normal(ks[0], (D, H, Dh)) * s_q).astype(pd),
        "wk": (jax.random.normal(ks[1], (D, KV, Dh)) * s_q).astype(pd),
        "wv": (jax.random.normal(ks[2], (D, KV, Dh)) * s_q).astype(pd),
        "wo": (jax.random.normal(ks[3], (H, Dh, D)) * s_q).astype(pd),
    }
    a: Axes = {
        "wq": ("model_d", "heads", "head_dim"),
        "wk": ("model_d", "kv_heads", "head_dim"),
        "wv": ("model_d", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "model_d"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), pd)
        p["bk"] = jnp.zeros((KV, Dh), pd)
        p["bv"] = jnp.zeros((KV, Dh), pd)
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    return p, a


def attention_forward(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,                       # (B, S, D)
    *,
    positions: jax.Array,               # (B, S)
    vision_kv: Optional[jax.Array] = None,   # (B, Nv, D) for cross layers
) -> tuple[jax.Array, dict]:
    """Full-sequence (train / prefill) attention. Returns (out, cache_state)."""
    B, S, D = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if spec.attn_type == "cross":
        src = vision_kv
    else:
        src = x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    if spec.attn_type != "cross":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        window = cfg.sliding_window if spec.attn_type == "local" else None
        out = ops.flash_attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap)
        cache = {"k": k, "v": v}
    else:
        out = ops.flash_attention(
            q, k, v, causal=False, window=None, softcap=cfg.attn_softcap)
        cache = {"k": k, "v": v}      # cross KV is static across decode
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return constrain(out, ("batch", "seq", None)), cache


def attention_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,                      # (B, 1, D)
    cache: dict,                       # {"k": (B,Smax,KV,Dh), "v": ...}
    lengths: jax.Array,                # (B,) tokens already in cache
    append: bool = False,
) -> tuple[jax.Array, dict]:
    """One decode step. ``append=False``: the new token's K/V is scattered
    into the cache before attention (cache flows through the layer scan —
    baseline). ``append=True`` (§Perf "cacheappend"): the cache is read-only
    here; the new token is merged into the softmax analytically and
    {"k_new","v_new"} deltas are returned for one batched commit outside the
    scan — removing the per-step full-cache rewrite the scan ys forces.
    """
    B, _, D = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if spec.attn_type == "cross":
        k_all, v_all = cache["k"], cache["v"]
        nv = k_all.shape[1]
        out = ops.decode_attention(
            q[:, 0], k_all, v_all, jnp.full((B,), nv, jnp.int32),
            softcap=cfg.attn_softcap)
        new_cache = {} if append else cache
    else:
        pos = lengths[:, None]                               # (B,1)
        q = rope(q, pos, cfg.rope_theta)
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
        if "bk" in p:
            k_new = k_new + p["bk"].astype(dt)
            v_new = v_new + p["bv"].astype(dt)
        k_new = rope(k_new, pos, cfg.rope_theta)
        window = cfg.sliding_window if spec.attn_type == "local" else None
        if append:
            out = ops.decode_attention(
                q[:, 0], cache["k"], cache["v"], lengths,
                window=window, softcap=cfg.attn_softcap,
                k_new=k_new[:, 0], v_new=v_new[:, 0])
            new_cache = {"k_new": k_new[:, 0], "v_new": v_new[:, 0]}
        else:
            bidx = jnp.arange(B)
            k_all = cache["k"].at[bidx, lengths].set(k_new[:, 0])
            v_all = cache["v"].at[bidx, lengths].set(v_new[:, 0])
            k_all = constrain(k_all, ("batch", "kv_seq", "kv_heads", None))
            v_all = constrain(v_all, ("batch", "kv_seq", "kv_heads", None))
            out = ops.decode_attention(
                q[:, 0], k_all, v_all, lengths + 1,
                window=window, softcap=cfg.attn_softcap)
            new_cache = {"k": k_all, "v": v_all}
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(dt))[:, None]
    return constrain(out, ("batch", None, None)), new_cache


def init_attention_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                         max_seq: int, dtype) -> tuple[dict, dict]:
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    if spec.attn_type == "cross":
        shape = (batch, max(cfg.n_vision_tokens, 1), KV, Dh)
    else:
        shape = (batch, max_seq, KV, Dh)
    axes = ("batch", "kv_seq", "kv_heads", None)
    return ({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
            {"k": axes, "v": axes})


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    D, F = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    s_in = (2.0 / (D + F)) ** 0.5
    gated = cfg.mlp_act in ("swiglu", "geglu")
    p: Params = {
        "w_up": (jax.random.normal(ks[0], (D, F)) * s_in).astype(pd),
        "w_down": (jax.random.normal(ks[1], (F, D)) * s_in).astype(pd),
    }
    a: Axes = {"w_up": ("model_d", "ff"), "w_down": ("ff", "model_d")}
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (D, F)) * s_in).astype(pd)
        a["w_gate"] = ("model_d", "ff")
    return p, a


def _act(name: str, x: jax.Array) -> jax.Array:
    if name in ("swiglu",):
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp_forward(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = _act(cfg.mlp_act, g) * h
    else:
        h = _act(cfg.mlp_act, h)
    h = constrain(h, ("batch", "seq", "ff"))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return constrain(out, ("batch", "seq", None))
