"""Period-grouped scanned decoder stack.

The stack is a sequence of groups; each group is `lax.scan` over stacked
per-period parameters.  One entry point per execution mode:

  * ``loss_fn`` / ``forward(mode="train")``   — teacher-forced training
  * ``prefill``                                — full sequence, returns cache
  * ``decode_step``                            — one token against the cache

Caches generalize across families: attention layers carry (k, v) buffers,
Mamba layers carry (conv, h) states, RWKV layers carry (wkv, shifts).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Any


# ===========================================================================
# Init
# ===========================================================================
def _init_layer(cfg: ModelConfig, spec: LayerSpec, key):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: dict = {}
    a: dict = {}
    p["norm1"], a["norm1"] = L._norm_init(D)
    if spec.kind == "attn":
        p["mixer"], a["mixer"] = L.init_attention(cfg, spec, ks[0])
    elif spec.kind == "mamba":
        p["mixer"], a["mixer"] = SSM.init_mamba(cfg, ks[0])
    elif spec.kind == "rwkv":
        p["mixer"], a["mixer"] = SSM.init_rwkv(cfg, ks[0])
    else:
        raise ValueError(spec.kind)
    if spec.kind != "rwkv":
        if spec.mlp == "dense":
            p["norm2"], a["norm2"] = L._norm_init(D)
            p["mlp"], a["mlp"] = L.init_mlp(cfg, ks[1])
        elif spec.mlp == "moe":
            p["norm2"], a["norm2"] = L._norm_init(D)
            p["mlp"], a["mlp"] = MOE.init_moe(cfg, ks[1])
    else:
        p["norm2"], a["norm2"] = L._norm_init(D)      # rwkv channel-mix norm
    if cfg.use_post_norms:
        p["post_norm1"], a["post_norm1"] = L._norm_init(D)
        p["post_norm2"], a["post_norm2"] = L._norm_init(D)
    return p, a


def init_params(cfg: ModelConfig, key) -> Params:
    return _init(cfg, key)[0]


def param_axes(cfg: ModelConfig) -> Any:
    """Logical-axes pytree matching init_params' structure.

    Axes depend only on the config's *structure* (which sub-params exist),
    which `reduced()` preserves — so build them from the tiny config to avoid
    allocating full-size parameters.
    """
    small = cfg.reduced(repeat_cap=1)
    return _init(small, jax.random.PRNGKey(0))[1]


def _padded_vocab(cfg: ModelConfig) -> int:
    if cfg.vocab_pad_to:
        return -(-cfg.vocab_size // cfg.vocab_pad_to) * cfg.vocab_pad_to
    return cfg.vocab_size


def _init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8 + len(cfg.groups))
    pd = jnp.dtype(cfg.param_dtype)
    D, V = cfg.d_model, _padded_vocab(cfg)
    p: dict = {}
    a: dict = {}
    if cfg.n_codebooks:
        p["embed"] = (jax.random.normal(ks[0], (cfg.n_codebooks, V, D)) * 0.02).astype(pd)
        a["embed"] = (None, "vocab", "model_d")
    else:
        p["embed"] = (jax.random.normal(ks[0], (V, D)) * 0.02).astype(pd)
        a["embed"] = ("vocab", "model_d")
    if cfg.n_vision_tokens:
        p["vision_proj"] = (jax.random.normal(ks[1], (D, D)) * (D ** -0.5)).astype(pd)
        a["vision_proj"] = ("model_d", None)
    p["final_norm"] = jnp.ones((D,), jnp.float32)
    a["final_norm"] = ("model_d",)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            p["lm_head"] = (jax.random.normal(ks[2], (cfg.n_codebooks, D, V)) * 0.02).astype(pd)
            a["lm_head"] = (None, "model_d", "vocab")
        else:
            p["lm_head"] = (jax.random.normal(ks[2], (D, V)) * 0.02).astype(pd)
            a["lm_head"] = ("model_d", "vocab")

    for gi, (period, rep) in enumerate(cfg.groups):
        gkey = ks[8 + gi]
        reps_p = []
        for r in range(rep):
            rkey = jax.random.fold_in(gkey, r)
            layer_ps = []
            for li, spec in enumerate(period):
                lp, la = _init_layer(cfg, spec, jax.random.fold_in(rkey, li))
                layer_ps.append(lp)
            reps_p.append(tuple(layer_ps))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reps_p)
        p[f"g{gi}"] = stacked
        # axes: same per-layer axes with a leading "layers" dim.
        # _init_layer was already called above for every repeat; rebuild the
        # axes tree from the first repeat's structure (axes are static).
        layer_axes = tuple(
            _init_layer(cfg.reduced(repeat_cap=1), spec,
                        jax.random.PRNGKey(0))[1]
            for spec in period)
        a[f"g{gi}"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax),
            layer_axes,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v))
    return p, a


def abstract_params(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of the params — no allocation."""
    return jax.eval_shape(
        lambda k: _init(cfg, k)[0], jax.random.PRNGKey(0))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    tree = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.n_experts:
            keys = "/".join(str(k) for k in path)
            if any(w in keys for w in ("w_gate", "w_up", "w_down")) and (
                    "mlp" in keys):
                # expert weights: only top_k of n_experts active per token
                n = n * cfg.moe_top_k // cfg.n_experts
        total += n
    return total


# ===========================================================================
# Layer application
# ===========================================================================
def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p, x, state, *, mode,
                 positions, lengths, vision_kv, append=False):
    aux = {"lb_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}
    h_in = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        if mode == "decode":
            mix_out, new_mix_state = L.attention_decode(
                cfg, spec, p["mixer"], h_in, state["mixer"], lengths,
                append=append)
        else:
            mix_out, new_mix_state = L.attention_forward(
                cfg, spec, p["mixer"], h_in,
                positions=positions, vision_kv=vision_kv)
    elif spec.kind == "mamba":
        st = state["mixer"] if state is not None else SSM.init_mamba_state(
            cfg, x.shape[0])[0]
        mix_out, new_mix_state = SSM.mamba_forward(cfg, p["mixer"], h_in, st)
    elif spec.kind == "rwkv":
        st = state["mixer"] if state is not None else SSM.init_rwkv_state(
            cfg, x.shape[0])[0]
        tm_state = {"wkv": st["wkv"], "shift_tm": st["shift_tm"]}
        mix_out, tm_new = SSM.rwkv_time_mix(cfg, p["mixer"], h_in, tm_state)
        x = x + mix_out
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        cm_out, cm_new = SSM.rwkv_channel_mix(
            cfg, p["mixer"], h2, {"shift_cm": st["shift_cm"]})
        x = constrain(x + cm_out, ("batch", "seq", None))
        new_state = {"mixer": {**tm_new, **cm_new}}
        return x, new_state, aux
    else:
        raise ValueError(spec.kind)

    if cfg.use_post_norms:
        mix_out = L.rms_norm(mix_out, p["post_norm1"], cfg.norm_eps)
    x = x + mix_out

    if spec.mlp != "none":
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.mlp == "dense":
            mlp_out = L.mlp_forward(cfg, p["mlp"], h2)
        else:
            mlp_out, moe_aux = MOE.moe_forward(cfg, p["mlp"], h2)
            aux = {k: aux[k] + moe_aux[k] for k in aux}
        if cfg.use_post_norms:
            mlp_out = L.rms_norm(mlp_out, p["post_norm2"], cfg.norm_eps)
        x = x + mlp_out
    x = constrain(x, ("batch", "seq", None))
    return x, {"mixer": new_mix_state}, aux


def _init_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_seq: int):
    cdt = jnp.dtype(cfg.dtype)
    if spec.kind == "attn":
        st, ax = L.init_attention_cache(cfg, spec, batch, max_seq, cdt)
    elif spec.kind == "mamba":
        st, ax = SSM.init_mamba_state(cfg, batch)
    elif spec.kind == "rwkv":
        st, ax = SSM.init_rwkv_state(cfg, batch)
    else:
        raise ValueError(spec.kind)
    return {"mixer": st}, {"mixer": ax}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Zeroed decode cache + matching logical-axes pytree."""
    cache: dict = {}
    axes: dict = {}
    for gi, (period, rep) in enumerate(cfg.groups):
        sts, axs = [], []
        for spec in period:
            st, ax = _init_layer_state(cfg, spec, batch, max_seq)
            sts.append(st)
            axs.append(ax)
        cache[f"g{gi}"] = jax.tree.map(
            lambda s: jnp.broadcast_to(s[None], (rep,) + s.shape).copy()
            if rep > 1 else s[None],
            tuple(sts))
        axes[f"g{gi}"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax),
            tuple(axs),
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v))
    return cache, axes


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq)[0])


def cache_axes(cfg: ModelConfig):
    """Logical-axes pytree matching init_cache's structure (no allocation of
    full-size buffers — built from the structure-preserving reduced config)."""
    return init_cache(cfg.reduced(repeat_cap=1), batch=1, max_seq=8)[1]


def cache_insert(cfg: ModelConfig, cache, prefill_cache, slot, length):
    """Write a single-sequence prefill cache (batch==1) into batch slot
    ``slot`` of a decode cache. ``length`` = prompt tokens (static int).

    Self-attention leaves are (rep, B, S, KV, Dh): the first `length`
    positions are written; recurrent/cross/shift states are written whole.
    """
    new_cache = {}
    for gi, (period, rep) in enumerate(cfg.groups):
        def merge(spec_idx):
            spec = period[spec_idx]
            dst = cache[f"g{gi}"][spec_idx]["mixer"]
            src = prefill_cache[f"g{gi}"][spec_idx]["mixer"]
            out = {}
            for k, d in dst.items():
                s = src[k]
                if spec.kind == "attn" and spec.attn_type != "cross" and k in ("k", "v"):
                    out[k] = d.at[:, slot, :length].set(
                        s[:, 0, :length].astype(d.dtype))
                else:
                    out[k] = d.at[:, slot].set(s[:, 0].astype(d.dtype))
            return {"mixer": out}

        new_cache[f"g{gi}"] = tuple(merge(i) for i in range(len(period)))
    return new_cache


# ===========================================================================
# Stack
# ===========================================================================
def _run_group(cfg: ModelConfig, period, stacked_p, h, *, mode, positions,
               lengths, vision_kv, stacked_state=None, append=False):
    """Scan over the group's repeats. Returns (h, new_states|None, aux)."""

    def body(carry, xs):
        h = carry
        if mode == "decode":
            p_per, st_per = xs
        else:
            p_per, st_per = xs, None
        new_states = []
        aux_tot = {"lb_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}
        for i, spec in enumerate(period):
            st_i = st_per[i] if st_per is not None else None
            h, st_new, aux = _apply_layer(
                cfg, spec, p_per[i], h, st_i, mode=mode,
                positions=positions, lengths=lengths, vision_kv=vision_kv,
                append=append)
            new_states.append(st_new)
            aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        ys = (tuple(new_states), aux_tot) if mode in ("prefill", "decode") \
            else aux_tot
        return h, ys

    if cfg.remat and mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stacked_p, stacked_state) if mode == "decode" else stacked_p
    h, ys = jax.lax.scan(body, h, xs)
    if mode in ("prefill", "decode"):
        states, aux = ys
        aux = jax.tree.map(jnp.sum, aux)
        return h, states, aux
    aux = jax.tree.map(jnp.sum, ys)
    return h, None, aux


def _embed(cfg: ModelConfig, params, tokens):
    cdt = jnp.dtype(cfg.dtype)
    emb = params["embed"].astype(cdt)
    if cfg.n_codebooks:
        # tokens: (B, S, C)
        parts = [emb[c][tokens[..., c]] for c in range(cfg.n_codebooks)]
        h = sum(parts)
    else:
        h = emb[tokens]
    return constrain(h, ("batch", "seq", None))


def _unembed(cfg: ModelConfig, params, h):
    cdt = h.dtype
    if cfg.tie_embeddings:
        w = params["embed"].astype(cdt)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
    elif cfg.n_codebooks:
        w = params["lm_head"].astype(cdt)
        logits = jnp.einsum("bsd,cdv->bscv", h, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(cdt))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap).astype(logits.dtype)
    Vp = logits.shape[-1]
    if Vp != cfg.vocab_size:
        # mask padded vocab rows out of the softmax (and argmax sampling)
        pad_mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    axes = ("batch", "seq", None, "vocab") if cfg.n_codebooks else (
        "batch", "seq", "vocab")
    return constrain(logits, axes)


def forward(cfg: ModelConfig, params, tokens, *, vision_embeds=None,
            mode: str = "train"):
    """Full-sequence pass. Returns (logits, cache|None, aux)."""
    h = _embed(cfg, params, tokens)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    vision_kv = None
    if cfg.n_vision_tokens:
        assert vision_embeds is not None, "vlm requires vision_embeds"
        vision_kv = jnp.einsum(
            "bnd,de->bne", vision_embeds.astype(h.dtype),
            params["vision_proj"].astype(h.dtype))
    caches = {}
    aux_tot = {"lb_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}
    for gi, (period, rep) in enumerate(cfg.groups):
        h, states, aux = _run_group(
            cfg, period, params[f"g{gi}"], h, mode=mode,
            positions=positions, lengths=None, vision_kv=vision_kv)
        if states is not None:
            caches[f"g{gi}"] = states
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)
    return logits, (caches if mode == "prefill" else None), aux_tot


def prefill(cfg: ModelConfig, params, tokens, *, vision_embeds=None):
    """Returns (logits, cache). Cache seq capacity == prompt length."""
    logits, cache, _ = forward(cfg, params, tokens,
                               vision_embeds=vision_embeds, mode="prefill")
    return logits, cache


_CACHE_APPEND_DEFAULT = False


def set_cache_append(enabled: bool) -> None:
    """§Perf lever (variant "cacheappend"): read-only cache inside the layer
    scan + one batched commit per group — see attention_decode."""
    global _CACHE_APPEND_DEFAULT
    _CACHE_APPEND_DEFAULT = enabled


def decode_step(cfg: ModelConfig, params, cache, tokens, lengths,
                append: bool | None = None):
    """One decode step.

    tokens: (B,) int32 — or (B, C) for codebook models.
    lengths: (B,) tokens already in the cache (i.e. position of new token).
    Returns (logits (B, V) or (B, C, V), new_cache).
    """
    append = _CACHE_APPEND_DEFAULT if append is None else append
    if cfg.n_codebooks:
        tok = tokens[:, None, :]            # (B,1,C)
    else:
        tok = tokens[:, None]               # (B,1)
    h = _embed(cfg, params, tok)
    B = h.shape[0]
    new_cache = {}
    for gi, (period, rep) in enumerate(cfg.groups):
        h, states, _ = _run_group(
            cfg, period, params[f"g{gi}"], h, mode="decode",
            positions=None, lengths=lengths, vision_kv=None,
            stacked_state=cache[f"g{gi}"], append=append)
        if not append:
            new_cache[f"g{gi}"] = states
        else:
            # commit per-layer deltas with ONE batched update per leaf —
            # the stacked cache is never rewritten inside the scan
            bidx = jnp.arange(B)
            merged = []
            for li, spec in enumerate(period):
                old = cache[f"g{gi}"][li]["mixer"]
                delta = states[li]["mixer"]
                if spec.kind == "attn" and spec.attn_type != "cross":
                    k = old["k"].at[:, bidx, lengths].set(delta["k_new"])
                    v = old["v"].at[:, bidx, lengths].set(delta["v_new"])
                    merged.append({"mixer": {"k": k, "v": v}})
                elif spec.kind == "attn":
                    merged.append({"mixer": old})        # cross: unchanged
                else:
                    merged.append({"mixer": delta})      # recurrent states
            new_cache[f"g{gi}"] = tuple(merged)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)
    return logits[:, 0], new_cache


# ===========================================================================
# Loss
# ===========================================================================
def loss_fn(cfg: ModelConfig, params, batch):
    """batch: {"tokens": (B,S[,C]), "labels": (B,S[,C]),
               optional "vision_embeds"}."""
    logits, _, aux = forward(
        cfg, params, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"), mode="train")
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    z = (lse ** 2).mean()
    loss = ce + 1e-4 * z + 1e-2 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
    return loss, {"ce": ce, "z": z, **aux}
