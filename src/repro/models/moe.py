"""Mixture-of-Experts layer.

Two execution paths:
  * ``capacity`` — production path: top-k gating, capacity-bounded dispatch
    using the *dual-index gather-only* formulation: integer index maps
    (slot_of: (T,K) token→slot, token_of_slot / tk_of_slot: slot→token) are
    built once with tiny 1-D integer sorts/scatters, and every float
    movement — dispatch, combine, and both backward passes — is a pure
    gather (custom VJPs).  No float scatter ever reaches XLA: float scatters
    with duplicate indices trigger the CPU scatter-expander's (elements, D)
    u32 index maps and SPMD update all-gathers, which dominated memory in
    the first dry-run iteration (see EXPERIMENTS.md §Perf).
    Tokens above capacity are dropped (GShard semantics).  Expert dim maps
    to the "model" mesh axis (EP); expert d_ff is FSDP-sharded over data.

    Scaling note (EXPERIMENTS.md §Perf kimi iter-3): with *global* dispatch
    indices, SPMD cannot prove gather locality and all-gathers the token
    tensors per layer.  ``cfg.moe_block_dispatch = nb`` switches to
    block-batched dispatch: the index build + gathers are vmapped over nb
    token blocks with per-block capacity (GShard group-capacity semantics),
    so every gather carries the sharded data axis as a batch dim and
    partitions locally — measured 2.55× on kimi-k2 train_4k's dominant
    (collective) term.
  * ``dense`` — oracle path for tests: every expert applied to every token.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops

Params = dict
Axes = dict


def init_moe(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s_in = (2.0 / (D + F)) ** 0.5
    p: Params = {
        "router": (jax.random.normal(ks[0], (D, E)) * (D ** -0.5)).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) * s_in).astype(pd),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) * s_in).astype(pd),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) * s_in).astype(pd),
    }
    a: Axes = {
        "router": ("model_d", None),
        "w_gate": ("experts", "model_d", "expert_ff"),
        "w_up": ("experts", "model_d", "expert_ff"),
        "w_down": ("experts", "expert_ff", "model_d"),
    }
    return p, a


def _f0(x):
    return np.zeros(x.shape, jax.dtypes.float0)


# ---------------------------------------------------------------------------
# Index maps (integers only; tiny)
# ---------------------------------------------------------------------------
def build_dispatch_indices(idx: jax.Array, E: int, cap: int):
    """idx: (T, K) expert choices. Returns
    slot_of: (T, K) destination slot in [0, E*cap] (E*cap = dropped),
    token_of_slot: (E*cap+1,) source token in [0, T] (T = empty slot),
    tk_of_slot: (E*cap+1,) flat (t*K+k) index in [0, T*K] (T*K = empty)."""
    T, K = idx.shape
    TK = T * K
    flat_expert = idx.reshape(TK)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    counts = jnp.zeros((E,), jnp.int32).at[sorted_expert].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(TK, dtype=jnp.int32) - starts[sorted_expert]
    keep = pos < cap
    slot_sorted = jnp.where(keep, sorted_expert * cap + pos, E * cap)
    inv = jnp.argsort(order, stable=True)
    slot_of = slot_sorted[inv].reshape(T, K)
    token_of_slot = jnp.full((E * cap + 1,), T, jnp.int32).at[
        slot_sorted].set(jnp.where(keep, (order // K).astype(jnp.int32), T))
    token_of_slot = token_of_slot.at[E * cap].set(T)
    tk_of_slot = jnp.full((E * cap + 1,), TK, jnp.int32).at[
        slot_sorted].set(jnp.where(keep, order.astype(jnp.int32), TK))
    tk_of_slot = tk_of_slot.at[E * cap].set(TK)
    return slot_of, token_of_slot, tk_of_slot


# ---------------------------------------------------------------------------
# Gather-only dispatch / combine with custom VJPs
# ---------------------------------------------------------------------------
@jax.custom_vjp
def _dispatch(x_pad, token_of_slot, slot_of):
    """x_pad: (T+1, D) with zero pad row -> (E*cap+1, D)."""
    return x_pad[token_of_slot]


def _dispatch_fwd(x_pad, token_of_slot, slot_of):
    return x_pad[token_of_slot], (token_of_slot, slot_of)


def _dispatch_bwd(res, dy):
    token_of_slot, slot_of = res
    T, K = slot_of.shape
    dx = sum(dy[slot_of[:, k]] for k in range(K))          # gathers only
    dx_pad = jnp.concatenate([dx, jnp.zeros((1,) + dx.shape[1:], dx.dtype)])
    return dx_pad, _f0(token_of_slot), _f0(slot_of)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine(y_pad, w, slot_of, token_of_slot, tk_of_slot):
    """y_pad: (E*cap+1, D) zero pad row; w: (T, K) -> out (T, D)."""
    K = w.shape[1]
    out = sum((w[:, k, None] * y_pad[slot_of[:, k]].astype(w.dtype))
              for k in range(K))
    return out


def _combine_fwd(y_pad, w, slot_of, token_of_slot, tk_of_slot):
    return (_combine(y_pad, w, slot_of, token_of_slot, tk_of_slot),
            (y_pad, w, slot_of, token_of_slot, tk_of_slot))


def _combine_bwd(res, dout):
    y_pad, w, slot_of, token_of_slot, tk_of_slot = res
    T, K = w.shape
    dw = jnp.stack(
        [jnp.sum(dout * y_pad[slot_of[:, k]].astype(dout.dtype), axis=-1)
         for k in range(K)], axis=1)
    w_flat_pad = jnp.concatenate([w.reshape(T * K), jnp.zeros((1,), w.dtype)])
    dout_pad = jnp.concatenate(
        [dout, jnp.zeros((1,) + dout.shape[1:], dout.dtype)])
    dy_pad = (w_flat_pad[tk_of_slot][:, None].astype(dout.dtype)
              * dout_pad[token_of_slot]).astype(y_pad.dtype)
    return (dy_pad, dw.astype(w.dtype), _f0(slot_of), _f0(token_of_slot),
            _f0(tk_of_slot))


_combine.defvjp(_combine_fwd, _combine_bwd)


def _expert_ffn(cfg: ModelConfig, p: Params, xs: jax.Array) -> jax.Array:
    """xs: (E, C, D) -> (E, C, D); per-expert gated FFN via batched einsum."""
    dt = xs.dtype
    g = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(dt))
    h = jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * h
    h = constrain(h, ("experts", "expert_cap", "ff"))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))


def moe_forward(cfg: ModelConfig, p: Params, x: jax.Array):
    """x: (B, S, D) -> (out (B,S,D), aux_losses dict)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    x_flat = constrain(x.reshape(T, D), ("flat_tokens", None))
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"])
    logits = constrain(logits, ("flat_tokens", None))
    weights, idx, aux = ops.moe_gating(logits, K)        # (T,K) f32, (T,K) i32

    if cfg.moe_impl == "dense":
        dt = x.dtype
        g = jnp.einsum("td,edf->tef", x_flat, p["w_gate"].astype(dt))
        h = jnp.einsum("td,edf->tef", x_flat, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * h
        y_all = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(dt))
        gate_full = jnp.zeros((T, E), jnp.float32)
        gate_full = gate_full.at[jnp.arange(T)[:, None], idx].add(weights)
        out = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), gate_full)
        return out.reshape(B, S, D).astype(x.dtype), aux

    nb = cfg.moe_block_dispatch
    if nb and T % nb == 0 and T // nb >= E // max(1, K):
        # ---- block-batched dispatch (group capacity, locality-provable) ---
        Tb = T // nb
        cap = int(cfg.capacity_factor * Tb * K / E) + 1
        cap = max(8, -(-cap // 8) * 8)
        cap = min(cap, Tb * K)
        x_blk = constrain(x_flat.reshape(nb, Tb, D),
                          ("flat_tokens", None, None))
        idx_blk = idx.reshape(nb, Tb, K)
        w_blk = weights.reshape(nb, Tb, K).astype(x.dtype)
        slot_of, token_of_slot, tk_of_slot = jax.vmap(
            build_dispatch_indices, in_axes=(0, None, None))(idx_blk, E, cap)
        x_pad = jnp.concatenate(
            [x_blk, jnp.zeros((nb, 1, D), x_blk.dtype)], axis=1)
        disp = jax.vmap(_dispatch)(x_pad, token_of_slot, slot_of)
        disp = disp[:, :-1].reshape(nb, E, cap, D)
        disp = jnp.transpose(disp, (1, 0, 2, 3)).reshape(E, nb * cap, D)
        disp = constrain(disp, ("experts", "expert_cap", None))

        y = _expert_ffn(cfg, p, disp)
        y = constrain(y, ("experts", "expert_cap", None))

        y_blk = jnp.transpose(
            y.reshape(E, nb, cap, D), (1, 0, 2, 3)).reshape(nb, E * cap, D)
        y_pad = jnp.concatenate(
            [y_blk, jnp.zeros((nb, 1, D), y.dtype)], axis=1)
        out = jax.vmap(_combine)(y_pad, w_blk, slot_of, token_of_slot,
                                 tk_of_slot)                # (nb, Tb, D)
        out = constrain(out.reshape(T, D), ("flat_tokens", None))
        out = out.reshape(B, S, D)
        return constrain(out, ("batch", "seq", None)), aux

    # ---- capacity-based gather-only dispatch -------------------------------
    cap = int(cfg.capacity_factor * T * K / E) + 1
    cap = max(8, -(-cap // 8) * 8)                       # round up to 8
    cap = min(cap, T * K)

    slot_of, token_of_slot, tk_of_slot = build_dispatch_indices(idx, E, cap)
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, D), x_flat.dtype)])
    dispatched = _dispatch(x_pad, token_of_slot, slot_of)   # (E*cap+1, D)
    dispatched = dispatched[:-1].reshape(E, cap, D)
    dispatched = constrain(dispatched, ("experts", "expert_cap", None))

    y = _expert_ffn(cfg, p, dispatched)                     # (E, cap, D)
    y = constrain(y, ("experts", "expert_cap", None))

    y_pad = jnp.concatenate(
        [y.reshape(E * cap, D), jnp.zeros((1, D), y.dtype)])
    out = _combine(y_pad, weights.astype(x.dtype), slot_of,
                   token_of_slot, tk_of_slot)               # (T, D)
    out = constrain(out, ("flat_tokens", None)).reshape(B, S, D)
    return constrain(out, ("batch", "seq", None)), aux
