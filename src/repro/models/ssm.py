"""State-space / linear-recurrence blocks: Mamba (Jamba's mixer) and RWKV6.

Both expose:  init_* -> (params, axes);  *_forward (full sequence, returns
final recurrent state for prefill→decode handoff);  *_decode (single token);
init_*_state -> (state, axes).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops

Params = dict
Axes = dict


# ===========================================================================
# Mamba
# ===========================================================================
def init_mamba(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    D, Din, N, R, K = (cfg.d_model, cfg.d_inner, cfg.mamba_d_state,
                       cfg.dt_rank, cfg.mamba_conv)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: Params = {
        "in_proj": (jax.random.normal(ks[0], (D, 2 * Din)) * (D ** -0.5)).astype(pd),
        "conv_w": (jax.random.normal(ks[1], (K, Din)) * (K ** -0.5)).astype(pd),
        "conv_b": jnp.zeros((Din,), pd),
        "x_proj": (jax.random.normal(ks[2], (Din, R + 2 * N)) * (Din ** -0.5)).astype(pd),
        "dt_w": (jax.random.normal(ks[3], (R, Din)) * (R ** -0.5)).astype(pd),
        "dt_bias": jnp.full((Din,), math.log(math.expm1(0.01)), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (Din, N))).astype(jnp.float32),
        "Dskip": jnp.ones((Din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (Din, D)) * (Din ** -0.5)).astype(pd),
    }
    a: Axes = {
        "in_proj": ("model_d", "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", None),
        "dt_w": (None, "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", "state"),
        "Dskip": ("d_inner",),
        "out_proj": ("d_inner", "model_d"),
    }
    return p, a


def _mamba_conv(p: Params, x_in: jax.Array, conv_state: jax.Array):
    """Causal depthwise conv, kernel K (small, unrolled).

    x_in: (B, S, Din); conv_state: (B, K-1, Din) trailing context.
    Returns (conv_out (B,S,Din), new_state (B,K-1,Din)).
    """
    K = p["conv_w"].shape[0]
    dt = x_in.dtype
    S = x_in.shape[1]
    padded = jnp.concatenate([conv_state.astype(dt), x_in], axis=1)
    out = p["conv_b"].astype(dt)[None, None]
    w = p["conv_w"].astype(dt)
    out = sum(w[i][None, None] * jax.lax.dynamic_slice_in_dim(padded, i, S, 1)
              for i in range(K)) + out
    new_state = padded[:, S:]
    return out, new_state


def mamba_forward(cfg: ModelConfig, p: Params, x: jax.Array, state: dict):
    B, S, D = x.shape
    dt_ = x.dtype
    Din, N, R = cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, ("batch", "seq", "d_inner"))
    conv_out, conv_new = _mamba_conv(p, x_in, state["conv"])
    xc = jax.nn.silu(conv_out)
    proj = jnp.einsum("bse,ef->bsf", xc, p["x_proj"].astype(dt_))
    dt_low, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_low.astype(jnp.float32), p["dt_w"].astype(jnp.float32))
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_fin = ops.ssm_scan(xc, dt, A, Bm, Cm, p["Dskip"], state["h"])
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    return (constrain(out, ("batch", "seq", None)),
            {"h": h_fin, "conv": conv_new})


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array, state: dict):
    return mamba_forward(cfg, p, x, state)     # S=1 path is identical


def init_mamba_state(cfg: ModelConfig, batch: int) -> tuple[dict, dict]:
    Din, N, K = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_conv
    return (
        {"h": jnp.zeros((batch, Din, N), jnp.float32),
         "conv": jnp.zeros((batch, K - 1, Din), jnp.dtype(cfg.dtype))},
        {"h": ("batch", "d_inner", None), "conv": ("batch", None, "d_inner")},
    )


# ===========================================================================
# RWKV6 ("Finch")
# ===========================================================================
def init_rwkv(cfg: ModelConfig, key) -> tuple[Params, Axes]:
    D, F = cfg.d_model, cfg.d_ff
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    mix, dec = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    s = D ** -0.5
    p: Params = {
        # time-mix (ddlerp) params
        "mu_x": jnp.zeros((D,), jnp.float32),
        "mu": jnp.zeros((5, D), jnp.float32),          # w,k,v,r,g
        "maa_w1": (jax.random.normal(ks[0], (D, 5 * mix)) * s * 0.1).astype(pd),
        "maa_w2": (jax.random.normal(ks[1], (5, mix, D)) * 0.1 * mix ** -0.5).astype(pd),
        # data-dependent decay
        "decay_base": jnp.full((D,), -1.0, jnp.float32),
        "decay_w1": (jax.random.normal(ks[2], (D, dec)) * s * 0.1).astype(pd),
        "decay_w2": (jax.random.normal(ks[3], (dec, D)) * 0.1 * dec ** -0.5).astype(pd),
        "u": (jax.random.normal(ks[4], (H, K)) * 0.1).astype(jnp.float32),
        "wr": (jax.random.normal(ks[5], (D, D)) * s).astype(pd),
        "wk": (jax.random.normal(ks[6], (D, D)) * s).astype(pd),
        "wv": (jax.random.normal(ks[7], (D, D)) * s).astype(pd),
        "wg": (jax.random.normal(ks[8], (D, D)) * s).astype(pd),
        "wo": (jax.random.normal(ks[9], (D, D)) * s).astype(pd),
        "ln_x_scale": jnp.ones((D,), jnp.float32),
        "ln_x_bias": jnp.zeros((D,), jnp.float32),
        # channel-mix
        "mu_k_c": jnp.zeros((D,), jnp.float32),
        "mu_r_c": jnp.zeros((D,), jnp.float32),
        "wk_c": (jax.random.normal(ks[10], (D, F)) * s).astype(pd),
        "wv_c": (jax.random.normal(ks[11], (F, D)) * (F ** -0.5)).astype(pd),
        "wr_c": (jax.random.normal(ks[0], (D, D)) * s).astype(pd),
    }
    a: Axes = {
        "mu_x": ("model_d",), "mu": (None, "model_d"),
        "maa_w1": ("model_d", None), "maa_w2": (None, None, "model_d"),
        "decay_base": ("model_d",),
        "decay_w1": ("model_d", None), "decay_w2": (None, "model_d"),
        "u": ("rwkv_heads", None),
        "wr": ("model_d", "d_inner"), "wk": ("model_d", "d_inner"),
        "wv": ("model_d", "d_inner"), "wg": ("model_d", "d_inner"),
        "wo": ("d_inner", "model_d"),
        "ln_x_scale": ("model_d",), "ln_x_bias": ("model_d",),
        "mu_k_c": ("model_d",), "mu_r_c": ("model_d",),
        "wk_c": ("model_d", "ff"), "wv_c": ("ff", "model_d"),
        "wr_c": ("model_d", "d_inner"),
    }
    return p, a


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """xx_t = x_{t-1}, with `last` (B, D) filling position 0."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(cfg: ModelConfig, p: Params, x: jax.Array, state: dict):
    """x: (B, S, D) pre-normed. Returns (out, new_state pieces)."""
    B, S, D = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = x.dtype
    mix = cfg.rwkv_lora_mix
    xx = _token_shift(x, state["shift_tm"].astype(dt))
    dx = xx - x
    x_base = x + dx * p["mu_x"].astype(dt)
    deltas = jnp.tanh(jnp.einsum("bsd,dm->bsm", x_base, p["maa_w1"].astype(dt)))
    deltas = deltas.reshape(B, S, 5, mix)
    deltas = jnp.einsum("bsim,imd->bsid", deltas, p["maa_w2"].astype(dt))
    mus = p["mu"].astype(dt)[None, None] + deltas            # (B,S,5,D)
    xw, xk, xv, xr, xg = [x + dx * mus[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)))

    w_log = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsm,md->bsd",
        jnp.tanh(jnp.einsum("bsd,dm->bsm", xw, p["decay_w1"].astype(dt))).astype(jnp.float32),
        p["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, K)         # decay in (0,1)

    r = constrain(r, ("batch", "seq", "rwkv_heads", None))
    k = constrain(k, ("batch", "seq", "rwkv_heads", None))
    v = constrain(v, ("batch", "seq", "rwkv_heads", None))
    out, S_new = ops.rwkv6_scan(r, k, v, w, p["u"], state["wkv"])

    # per-head groupnorm
    of = out.astype(jnp.float32)
    mean = of.mean(-1, keepdims=True)
    var = of.var(-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 64e-5)
    of = of.reshape(B, S, D) * p["ln_x_scale"] + p["ln_x_bias"]
    out = (of.astype(dt) * g)
    out = jnp.einsum("bsd,de->bse", out, p["wo"].astype(dt))
    return out, {"wkv": S_new, "shift_tm": x[:, -1]}


def rwkv_channel_mix(cfg: ModelConfig, p: Params, x: jax.Array, state: dict):
    dt = x.dtype
    xx = _token_shift(x, state["shift_cm"].astype(dt))
    dx = xx - x
    xk = x + dx * p["mu_k_c"].astype(dt)
    xr = x + dx * p["mu_r_c"].astype(dt)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk_c"].astype(dt))
    k = jax.nn.relu(k) ** 2
    k = constrain(k, ("batch", "seq", "ff"))
    v = jnp.einsum("bsf,fd->bsd", k, p["wv_c"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr_c"].astype(dt)))
    return r * v, {"shift_cm": x[:, -1]}


def init_rwkv_state(cfg: ModelConfig, batch: int) -> tuple[dict, dict]:
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    D = cfg.d_model
    cdt = jnp.dtype(cfg.dtype)
    return (
        {"wkv": jnp.zeros((batch, H, K, K), jnp.float32),
         "shift_tm": jnp.zeros((batch, D), cdt),
         "shift_cm": jnp.zeros((batch, D), cdt)},
        {"wkv": ("batch", "rwkv_heads", None, None),
         "shift_tm": ("batch", None), "shift_cm": ("batch", None)},
    )
