"""Sharded checkpointing with atomic commit and restore-time resharding.

Layout:  <dir>/step_<N>/
            manifest.json            tree structure, shapes, dtypes, step
            shard_<k>.npz            leaf arrays (flat key -> array)
            _COMMITTED               written last (atomic rename marker)

Fault-tolerance properties:
  * a crash mid-save never corrupts the latest checkpoint (tmp dir +
    os.replace, marker file written last),
  * `latest_step` ignores uncommitted/partial directories,
  * restore reshards: arrays are loaded on host then device_put with the
    *current* sharding (mesh/topology may differ from save time — elastic
    restart),
  * async mode overlaps serialization with training (thread pool); `wait()`
    provides a barrier before the next save or exit.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_MARKER = "_COMMITTED"
_MAX_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in leaves}


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[cf.Future] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._pool is None:
            self._write(step, host_tree)
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host_tree)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> None:
        flat = _flatten(host_tree)
        tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_",
                                    dir=self.dir))
        try:
            shards: list[dict[str, np.ndarray]] = [{}]
            sizes = [0]
            for k, v in flat.items():
                if sizes[-1] + v.nbytes > _MAX_SHARD_BYTES and shards[-1]:
                    shards.append({})
                    sizes.append(0)
                shards[-1][k] = v
                sizes[-1] += v.nbytes
            manifest = {
                "step": step,
                "n_shards": len(shards),
                "keys": {k: {"shard": si, "shape": list(v.shape),
                             "dtype": str(v.dtype)}
                         for si, sh in enumerate(shards)
                         for k, v in sh.items()},
            }
            for si, sh in enumerate(shards):
                np.savez(tmp / f"shard_{si}.npz",
                         **{k: v for k, v in sh.items()})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / _MARKER).write_text("ok")
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / _MARKER).exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (arrays or
        ShapeDtypeStructs). If ``shardings`` (matching pytree) is given,
        leaves are device_put with it — resharding across topologies."""
        d = self.dir / f"step_{step}"
        if not (d / _MARKER).exists():
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        manifest = json.loads((d / "manifest.json").read_text())
        cache: dict[int, Any] = {}

        def load(k: str) -> np.ndarray:
            info = manifest["keys"][k]
            si = info["shard"]
            if si not in cache:
                cache[si] = np.load(d / f"shard_{si}.npz")
            return cache[si][k]

        leaves = jax.tree_util.tree_leaves_with_path(like)
        flat_sh = (_flatten(shardings) if shardings is not None else {})
        out_flat = []
        for p, leaf in leaves:
            k = jax.tree_util.keystr(p)
            arr = load(k)
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype) if arr.dtype != want_dtype else arr
            if k in flat_sh and flat_sh[k] is not None:
                arr = jax.device_put(arr, flat_sh[k])
            out_flat.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, out_flat)
